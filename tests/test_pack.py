"""IMPack: codecs, the decode-and-count kernel, encoded stores, the
compress-before-evict pressure ladder, snapshot elasticity, and the
engine/stream integration of the packed and compressed at-rest formats.

The headline invariant everywhere: the at-rest representation never
changes an answer.  Counts are integers in f32, so a packed or
compressed arena holding the same RRR sets as a bitmap yields bitwise
identical counters, argmaxes, tie-breaks, seeds, and influence — the
formats only change how many bytes those sets occupy.

Mesh-touching tests use however many devices the process has (1 in a
plain run, 4 under scripts/ci.sh's forced-4-device pass); the real
multi-device acceptance cells run through tests/force_mesh_check.py
``--store packed|compressed`` (see test_sharded_store.py and ci.sh).
"""
import dataclasses
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import obs
from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.core.pack import CompressedStore, PackedBitmapStore
from repro.core.pack.codec import (
    MIN_TOKEN_PAD, codec_for, pack_bits_np, token_decode_np, tokens_needed,
    unpack_bits_np,
)
from repro.core.store import (
    BitmapStore, ShardedStore, StorePressurePolicy, make_store,
    store_from_state,
)
from repro.graphs import rmat_graph
from repro.kernels import ops, ref
from repro.stream import StreamEngine, random_delta

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _bit_rows(rng, B, n, density=0.1):
    """Random uint8 0/1 rows, a few of them adversarial: all-zero,
    all-one (exercises the saturated-run token), single-bit."""
    rows = (rng.random((B, n)) < density).astype(np.uint8)
    if B >= 4:
        rows[0] = 0
        rows[1] = 1
        rows[2] = 0
        rows[2, n - 1] = 1
    return rows


def small_graph(seed=2):
    return rmat_graph(96, 768, seed=seed)


# ------------------------------------------------------------------ codecs --

@pytest.mark.parametrize("kind", ["packed", "compressed"])
@pytest.mark.parametrize("n", [5, 8, 96, 300])
def test_codec_roundtrip(rng, kind, n):
    """encode -> decode is the identity on bit rows for every width,
    including non-byte-aligned and multi-superblock ones; decode_cols
    and row_popcount agree with the decoded rows; the numpy decode
    matches the jnp one (the snapshot path uses it)."""
    bits = _bit_rows(rng, 16, n, density=0.3)
    s_pad = int(tokens_needed(jnp.asarray(bits)).max())
    codec = codec_for(kind, n, s_pad=max(s_pad, MIN_TOKEN_PAD))
    stored = np.asarray(codec.encode(jnp.asarray(bits)))
    assert stored.shape == (16, codec.width)
    assert stored.dtype == np.dtype(codec.dtype)
    back = np.asarray(codec.decode(jnp.asarray(stored)))
    np.testing.assert_array_equal(back, bits)
    np.testing.assert_array_equal(codec.decode_np(stored), bits)
    cols = jnp.asarray([0, n // 2, n - 1], jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(codec.decode_cols(jnp.asarray(stored), cols)),
        bits[:, np.asarray(cols)].astype(bool))
    np.testing.assert_array_equal(
        np.asarray(codec.row_popcount(jnp.asarray(stored))),
        bits.sum(axis=1))


def test_codec_np_jnp_agree(rng):
    """The numpy pack/unpack helpers (snapshot restore path) invert the
    jnp encoders bit-for-bit."""
    bits = _bit_rows(rng, 8, 77)
    packed = np.asarray(codec_for("packed", 77).encode(jnp.asarray(bits)))
    np.testing.assert_array_equal(packed, pack_bits_np(bits))
    np.testing.assert_array_equal(unpack_bits_np(packed, 77), bits)
    tok = codec_for("compressed", 77, s_pad=32)
    T = np.asarray(tok.encode(jnp.asarray(bits)))
    np.testing.assert_array_equal(token_decode_np(T, 77), bits)


# ----------------------------------------------------- decode-and-count ----

@pytest.mark.parametrize("kind", ["packed", "compressed"])
def test_count_kernel_interpret_matches_oracle(rng, kind):
    """The Pallas decode-and-count kernel under ``interpret=True``
    matches both the jnp oracle and a numpy ground truth — the CPU
    validation gate for the TPU path."""
    n, B = 200, 64
    bits = _bit_rows(rng, B, n, density=0.15)
    alive = (rng.random(B) < 0.7).astype(np.float32)
    want = (alive[:, None] * bits).sum(axis=0).astype(np.int32)
    codec = codec_for(kind, n, s_pad=max(
        int(tokens_needed(jnp.asarray(bits)).max()), MIN_TOKEN_PAD))
    stored = codec.encode(jnp.asarray(bits))
    fn = ops.packed_count if kind == "packed" else ops.token_count
    oracle = (ref.packed_count_ref if kind == "packed"
              else ref.token_count_ref)(stored, jnp.asarray(alive), n)
    interp = fn(stored, jnp.asarray(alive), n=n, interpret=True)
    np.testing.assert_array_equal(np.asarray(oracle), want)
    np.testing.assert_array_equal(np.asarray(interp), want)


# ---------------------------------------------- engine: unchanged answers --

@pytest.mark.parametrize("model,backend", [
    ("IC", None), ("IC", "pallas"), ("LT", None), ("WC", None)])
@pytest.mark.parametrize("store", ["packed", "compressed"])
def test_engine_equivalence_across_samplers(store, model, backend):
    """Across the sampler matrix, an engine on an encoded arena is
    seed-for-seed identical to the bitmap engine — seeds, influence,
    covered_frac, counter — for rebuild AND decremental selection."""
    g = small_graph()
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3, model=model,
                    backend=backend, adaptive_representation=False)
    ref_res = InfluenceEngine(g, cfg).run()
    eng = InfluenceEngine(g, dataclasses.replace(cfg, store=store))
    assert eng.store.representation == store
    res = eng.run()
    np.testing.assert_array_equal(ref_res.seeds, res.seeds)
    np.testing.assert_array_equal(ref_res.counter, res.counter)
    assert ref_res.influence == res.influence
    assert ref_res.covered_frac == res.covered_frac
    np.testing.assert_array_equal(
        InfluenceEngine(g, cfg).run().seeds,
        eng.select(5, method="decrement").seeds[:5])


@pytest.mark.parametrize("store", ["packed", "compressed"])
def test_engine_equivalence_on_local_mesh(store):
    """Same invariant through the sharded path with whatever devices
    the process has (ci.sh forces 4): encoded mesh tiles answer like
    the single-device bitmap."""
    g = small_graph()
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3)
    ref_res = InfluenceEngine(g, cfg).run()
    mesh = make_im_mesh(jax.device_count())
    eng = InfluenceEngine(g, dataclasses.replace(cfg, store=store),
                          **mesh_engine_kwargs(mesh))
    assert isinstance(eng.store, ShardedStore)
    assert eng.store.representation == store
    res = eng.run()
    np.testing.assert_array_equal(ref_res.seeds, res.seeds)
    np.testing.assert_array_equal(ref_res.counter, res.counter)


def test_adaptive_c4_still_picks_indices_over_packed_store():
    """The C4 adaptive chooser composes with encoded arenas: sparse
    rows flip selection to the index layout (decoded lazily from the
    packed arena), dense rows stay on the store's native layout —
    answers identical either way."""
    g = rmat_graph(128, 256, seed=1)           # sparse: tiny RRR sets
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3, store="packed",
                    adaptive_representation=True, sparse_rep_min_n=1,
                    switch_ratio=1)            # l_max < n flips to indices
    eng = InfluenceEngine(g, cfg)
    res = eng.run()
    assert res.representation == "indices"
    dense_cfg = dataclasses.replace(cfg, adaptive_representation=False)
    dense_res = InfluenceEngine(g, dense_cfg).run()
    assert dense_res.representation == "packed"
    np.testing.assert_array_equal(res.seeds, dense_res.seeds)


# ------------------------------------------------- pressure-ladder edges --

def _batches(rng, n, count, batch):
    return [_bit_rows(rng, batch, n) for _ in range(count // batch)]


def test_ladder_compresses_before_evicting(rng):
    """Compress-before-evict ordering: a write that would overflow the
    byte cap first morphs the arena down the ladder — bitmap tiles
    become packed tiles, 8x more rows fit the same byte budget — and
    nothing is evicted.  The resident rows survive the morph intact
    (exact counter over every batch ever written)."""
    n = 96
    mesh = make_im_mesh(jax.device_count())
    policy = StorePressurePolicy(max_bytes=48 * n, ladder=("packed",))
    store = make_store("sharded", n, mesh=mesh, theta_axes=("data",),
                       policy=policy)
    assert store.representation == "bitmap"
    assert store.row_cap == 48
    batches = _batches(rng, n, 48, 16)
    for b in batches:
        store.add_batch(jnp.asarray(b))
    assert store.count == 48 and store.representation == "bitmap"
    # the next batch does not fit at bitmap width -> the ladder fires
    extra = _bit_rows(rng, 16, n)
    store.add_batch(jnp.asarray(extra))
    assert store.representation == "packed"
    assert store.count == 64        # nothing evicted: width shrank instead
    assert store.row_cap == 8 * 48  # 8x more rows under the same bytes
    np.testing.assert_array_equal(
        np.asarray(store.counter),
        np.concatenate(batches + [extra]).sum(axis=0))


def test_ladder_staleness_first_then_fifo_eviction(rng):
    """Victim order is deterministic: dead rows are compacted away
    before any live row is touched, then the *oldest* live rows go
    FIFO.  With the ladder exhausted the surviving set is exactly the
    newest ``cap`` rows."""
    n = 96
    store = CompressedStore(n, policy=StorePressurePolicy(max_rows=48))
    rows = _bit_rows(rng, 48, n)
    store.add_batch(jnp.asarray(rows))
    # kill 8 stale rows in the middle: they must be reclaimed first
    dead = np.zeros(store.capacity, bool)
    dead[8:16] = True
    assert store.kill_rows(jnp.asarray(dead)) == 8
    incoming = _bit_rows(rng, 8, n)
    store.add_batch(jnp.asarray(incoming))     # fits via compaction alone
    assert store.count == 48 and store.dead == 0
    live_then = np.concatenate([rows[:8], rows[16:48], incoming])
    np.testing.assert_array_equal(np.asarray(store.counter),
                                  live_then.sum(axis=0))
    # now full of live rows: the next batch must evict the OLDEST 8
    incoming2 = _bit_rows(rng, 8, n)
    store.add_batch(jnp.asarray(incoming2))
    assert store.count == 48
    survivors = np.concatenate([live_then[8:], incoming2])
    np.testing.assert_array_equal(np.asarray(store.counter),
                                  survivors.sum(axis=0))


def test_sharded_per_shard_caps_with_packed_tiles(rng):
    """A byte budget caps *physical* per-row bytes, so packed tiles
    admit 8x the rows of bitmap tiles under the same budget; eviction
    under the cap stays per-shard FIFO and the counter stays exact."""
    n = 96
    mesh = make_im_mesh(jax.device_count())
    kw = dict(mesh=mesh, theta_axes=("data",))
    budget = StorePressurePolicy(max_bytes=64 * n)   # 64 bitmap rows
    bm = make_store("sharded", n, policy=budget, **kw)
    pk = make_store("sharded", n, codec="packed", policy=budget, **kw)
    assert pk.row_cap == 8 * bm.row_cap
    cap = pk.row_cap
    batches = _batches(rng, n, cap, cap // 4)
    for b in batches:
        pk.add_batch(jnp.asarray(b))
    assert pk.count == cap
    # one more batch: every shard evicts its oldest cap/(4D) local rows,
    # which is exactly its slice of the first batch -> the survivors are
    # batches[1:] plus the incoming rows, on every shard count
    extra = _bit_rows(rng, cap // 4, n)
    pk.add_batch(jnp.asarray(extra))
    assert pk.count == cap
    np.testing.assert_array_equal(
        np.asarray(pk.counter),
        np.concatenate(batches[1:] + [extra]).sum(axis=0))


def test_eviction_on_exactly_full_arena(rng):
    """Edge cases at the cap boundary: an exactly-full arena evicts
    exactly the incoming batch size; a batch larger than the whole cap
    raises instead of silently truncating."""
    n = 96
    store = PackedBitmapStore(n, policy=StorePressurePolicy(max_rows=32))
    rows = _bit_rows(rng, 32, n)
    store.add_batch(jnp.asarray(rows))
    assert store.count == store.row_cap == 32
    nxt = _bit_rows(rng, 8, n)
    store.add_batch(jnp.asarray(nxt))
    assert store.count == 32
    np.testing.assert_array_equal(
        np.asarray(store.counter),
        np.concatenate([rows[8:], nxt]).sum(axis=0))
    with pytest.raises(ValueError, match="exceeds the policy row cap"):
        store.add_batch(jnp.asarray(_bit_rows(rng, 33, n)))


# ------------------------------------------------------ snapshot matrix ----

@pytest.mark.parametrize("src_kind", ["bitmap", "packed", "compressed"])
@pytest.mark.parametrize("dst_kind", ["bitmap", "packed", "compressed"])
def test_snapshot_elasticity_across_kinds(rng, src_kind, dst_kind):
    """Any dense at-rest snapshot restores into any dense at-rest
    store (decoded rows are the interchange format) with identical
    counters and membership."""
    n = 96
    src = make_store(src_kind, n)
    rows = _bit_rows(rng, 40, n)
    src.add_batch(jnp.asarray(rows))
    dead = np.zeros(src.capacity, bool)
    dead[3:7] = True
    src.kill_rows(jnp.asarray(dead))
    dst = store_from_state(src.state(), kind=dst_kind)
    assert dst.representation == dst_kind
    np.testing.assert_array_equal(np.asarray(src.counter),
                                  np.asarray(dst.counter))
    S = jnp.asarray([[1, 5, 90], [0, 2, 4]], jnp.int32)
    np.testing.assert_array_equal(np.asarray(src.hits(S)),
                                  np.asarray(dst.hits(S)))


def test_engine_snapshot_roundtrip_packed_to_mesh(rng):
    """Engine-level elasticity: a packed single-device snapshot resumes
    on a mesh as compressed (and back) without changing selections."""
    g = small_graph()
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3, store="packed")
    eng = InfluenceEngine(g, cfg)
    res = eng.run()
    with tempfile.TemporaryDirectory() as d:
        eng.snapshot(d)
        mesh = make_im_mesh(jax.device_count())
        comp = InfluenceEngine(
            g, dataclasses.replace(cfg, store="compressed"),
            **mesh_engine_kwargs(mesh))
        assert comp.restore(d)
        assert comp.store.representation == "compressed"
        np.testing.assert_array_equal(res.seeds, comp.select(5).seeds)


def test_store_from_state_names_all_supported_combinations(rng):
    """The restore error is one coherent message naming every supported
    (representation, mesh) combination."""
    n = 96
    idx = make_store("indices", n)
    # sparse rows only: an all-ones row would widen l_pad past n
    idx.add_batch(jnp.asarray(
        (rng.random((8, n)) < 0.1).astype(np.uint8)))
    mesh = make_im_mesh(jax.device_count())
    with pytest.raises(ValueError) as ei:
        store_from_state(idx.state(), mesh=mesh, theta_axes=("data",))
    msg = str(ei.value)
    assert "(representation, mesh)" in msg
    for word in ("bitmap", "packed", "compressed", "indices", "sharded"):
        assert word in msg, f"{word!r} missing from: {msg}"


# ---------------------------------------------------------------- stream ----

def test_stream_invalidate_and_refresh_on_packed(rng):
    """Reverse-touch staleness queries decode membership in place on
    encoded arenas: a StreamEngine on packed rows marks the same rows
    stale and refreshes to the same seeds as the bitmap StreamEngine."""
    g = small_graph()
    cfg = IMMConfig(k=5, batch=64, max_theta=512, seed=7)
    ref_s = StreamEngine(g, cfg)
    pk = StreamEngine(g, dataclasses.replace(cfg, store="packed"))
    ref_s.extend(256), pk.extend(256)
    d = random_delta(ref_s.graph, np.random.default_rng(12),
                     inserts=3, deletes=3, reweights=3)
    stale_ref = ref_s.apply_delta(d)
    stale_pk = pk.apply_delta(d)
    assert stale_ref == stale_pk
    ref_s.refresh(), pk.refresh()
    np.testing.assert_array_equal(ref_s.select(5).seeds,
                                  pk.select(5).seeds)


# ------------------------------------------------------------------- obs ----

def test_obs_gauges_report_physical_bytes(rng):
    """The byte gauges report encoded (physical) arena bytes — 8x less
    for packed than bitmap — and the compress_ratio gauge reports
    logical bits over physical bytes."""
    n = 96
    try:
        obs.enable()
        vals = {}
        for kind in ("bitmap", "packed"):
            obs.reset()
            obs.enable()
            store = make_store(kind, n)
            store.add_batch(jnp.asarray(_bit_rows(rng, 32, n)))
            snap = obs.snapshot()
            vals[kind] = {
                "arena": snap["gauges"]["store.arena_bytes"]["value"],
                "perdev": snap["gauges"]["store.bytes_per_device"]["value"],
                "ratio": snap["gauges"]["store.compress_ratio"]["value"],
            }
            assert vals[kind]["arena"] == store.capacity * store._row_bytes()
        assert vals["bitmap"]["arena"] == 8 * vals["packed"]["arena"]
        assert vals["packed"]["perdev"] == vals["packed"]["arena"]
        assert vals["packed"]["ratio"] == 8.0
        assert vals["bitmap"]["ratio"] == 1.0
    finally:
        obs.reset()


# ----------------------------------------- forced multi-device subprocess --

def _run_force_mesh(devices: int, mesh: str, store: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    inherited = " ".join(
        f for f in env.get("XLA_FLAGS", "").split()
        if not f.startswith("--xla_force_host_platform_device_count"))
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + inherited).strip()
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "force_mesh_check.py"),
         "--mesh", mesh, "--store", store],
        env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_packed_forced_4dev_subprocess():
    """1D acceptance cell for the packed tiles: 4 forced host devices,
    per-device buffers are (cap_local, ceil(n/8)) and answers match the
    single-device bitmap engine."""
    out = _run_force_mesh(4, "4", "packed")
    assert out["ok"] and out["store"] == "packed"


def test_compressed_forced_8dev_2x4_subprocess():
    """2D acceptance cell for the token tiles: a forced-8-device 2x4
    mesh runs compressed tiles over both arena axes, seed-for-seed with
    the single-device bitmap engine."""
    out = _run_force_mesh(8, "2x4", "compressed")
    assert out["ok"] and out["store"] == "compressed"
