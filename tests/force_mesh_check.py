"""Subprocess body for the forced multi-device ShardedStore checks.

Run by tests/test_sharded_store.py (and scripts/ci.sh) with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` so the mesh code
paths execute on real (host-platform) multi-device buffers even on
CPU-only runners.  ``--mesh RxC`` selects the layout (default ``4`` —
the historical 1D cell; ci.sh also runs ``--mesh 2x4`` under 8 forced
devices).  Asserts the C1 acceptance criteria:

  * the full ``(theta, n)`` arena never materializes on one device —
    per-device buffer shapes are ``(cap_local, n_local)`` with
    ``n_local = ceil(n / Dv)`` vertex columns (``n_local == n`` only on
    1D meshes);
  * sharded ``select(k)`` and ``influence(S)`` are seed-for-seed
    identical to ``BitmapStore`` + dense selection for a fixed
    ``cfg.seed``, including the true decremental sharded strategy;
  * edge-balanced vertex blocks (``cfg.partition="balanced"``) and
    overlap-off traversal (``cfg.overlap=False``) are bitwise identical
    to the equal/overlapped run — layout and scheduling never change an
    answer — and on the 2D rmat cell the balanced layout reports
    strictly lower per-tile edge imbalance;
  * snapshot/restore round-trips across layouts (this mesh -> 1D -> 1
    shard -> none) without changing answers;
  * the fused sample->write->count chain (``fused_pipeline="auto"``, the
    default) is bitwise identical to an explicitly-unfused run, and the
    ``fused-rebuild``/``fused-decrement`` selection strategies match
    their legacy spellings — including on the balanced 2D layout, where
    pad-column masks and partition offsets must not perturb either.

Prints one JSON line on success (consumed by the pytest wrapper).
"""
import argparse
import dataclasses
import json
import sys
import tempfile

import numpy as np
import jax

from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.core.store import BitmapStore, ShardedStore
from repro.graphs import balance_report, rmat_graph


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="4",
                    help="layout to check: an int (1D) or 'RxC' (2D)")
    ap.add_argument("--store", default="auto",
                    choices=("auto", "packed", "compressed"),
                    help="at-rest arena format for the sharded engine "
                         "('auto' = bitmap tiles; 'packed'/'compressed' "
                         "run the IMPack codecs on every mesh tile)")
    args = ap.parse_args(argv)

    mesh = make_im_mesh(args.mesh)
    n_dev = jax.device_count()
    want = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    assert n_dev == want, \
        f"mesh {args.mesh} wants {want} forced host devices, got {n_dev}"
    kw = mesh_engine_kwargs(mesh)

    g = rmat_graph(128, 1024, seed=4)
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3,
                    store=args.store)
    # the reference stays a single-device bitmap: the IMPack formats
    # must match IT, not just each other
    cfg_dense = dataclasses.replace(cfg, store="auto")

    dense = InfluenceEngine(g, cfg_dense)
    sharded = InfluenceEngine(g, cfg, **kw)
    assert isinstance(dense.store, BitmapStore)
    assert isinstance(sharded.store, ShardedStore)
    want_rep = "bitmap" if args.store == "auto" else args.store
    assert sharded.store.representation == want_rep

    r_dense, r_sharded = dense.run(), sharded.run()

    # --- seed-for-seed identity with BitmapStore + dense selection ------
    np.testing.assert_array_equal(r_dense.seeds, r_sharded.seeds)
    np.testing.assert_array_equal(r_dense.counter, r_sharded.counter)
    assert r_dense.theta == r_sharded.theta
    assert abs(r_dense.covered_frac - r_sharded.covered_frac) < 1e-7

    # --- the full arena never exists on one device ----------------------
    st = sharded.store
    shards = st.R.addressable_shards
    assert len(shards) == n_dev
    # per-device tiles are (cap_local, w_local) where w_local is the
    # codec's at-rest width (== n_local bit columns for bitmap tiles)
    assert all(s.data.shape == (st.cap_local, st.w_local) for s in shards), \
        [s.data.shape for s in shards]
    assert st.capacity == st.D * st.cap_local
    assert st.n_pad == st.Dv * st.n_local
    if args.store == "packed":
        # bit-packing actually shrank the resident tile
        assert st.w_local == -(-st.n_local // 8), (st.w_local, st.n_local)
    if st.Dv > 1:
        # 2D: every device holds only its n/Dv vertex columns
        assert st.n_local < g.n, (st.n_local, g.n)
    assert {tuple(s.data.shape) for s in st.sizes.addressable_shards} == \
        {(st.cap_local,)}
    # counter partials are tiled too (one (1, n_local) block per device)
    assert all(s.data.shape == (1, st.n_local)
               for s in st._counter.addressable_shards)

    # --- true decremental sharded strategy == rebuild == dense ----------
    sel_reb = sharded.select(5, method="rebuild")
    sel_dec = sharded.select(5, method="decrement")
    np.testing.assert_array_equal(sel_reb.seeds, sel_dec.seeds)
    np.testing.assert_array_equal(sel_reb.gains, sel_dec.gains)
    np.testing.assert_array_equal(
        sel_dec.seeds, dense.select(5, method="decrement").seeds)

    # --- fused pipeline (PR 10): auto is the default above — prove it
    # against an explicitly-unfused run, and the fused selection
    # strategies against their legacy spellings, on this mesh/store cell
    unfused = InfluenceEngine(
        g, dataclasses.replace(cfg, fused_pipeline="off"), **kw)
    r_unf = unfused.run()
    np.testing.assert_array_equal(r_sharded.seeds, r_unf.seeds)
    np.testing.assert_array_equal(r_sharded.counter, r_unf.counter)
    np.testing.assert_array_equal(
        sharded.select(5, method="fused-rebuild").seeds, sel_reb.seeds)
    np.testing.assert_array_equal(
        sharded.select(5, method="fused-rebuild").gains, sel_reb.gains)
    np.testing.assert_array_equal(
        sharded.select(5, method="fused-decrement").seeds, sel_dec.seeds)

    # --- layout & schedule invariance: balanced blocks, overlap off -----
    imb = {"equal": 1.0, "balanced": 1.0}
    if st.Dv > 1:
        bal = InfluenceEngine(
            g, dataclasses.replace(cfg, partition="balanced"), **kw)
        r_bal = bal.run()
        np.testing.assert_array_equal(r_dense.seeds, r_bal.seeds)
        np.testing.assert_array_equal(r_dense.counter, r_bal.counter)
        bst = bal.store
        assert not bst.partition.is_equal
        # boundaries are data-dependent but per-device tiles stay uniform
        assert all(s.data.shape == (bst.cap_local, bst.w_local)
                   for s in bst.R.addressable_shards)
        imb["equal"] = balance_report(g.edge_dst, g.n, st.Dv)["imbalance"]
        imb["balanced"] = balance_report(
            g.edge_dst, g.n, st.Dv, partition=bst.partition)["imbalance"]
        assert imb["balanced"] <= imb["equal"] + 1e-9, imb
        if imb["equal"] > 1.1:
            # rmat degrees are skewed: balancing must actually help
            assert imb["balanced"] < imb["equal"], imb
        # balanced + overlap-off together, still bitwise identical
        both = InfluenceEngine(
            g, dataclasses.replace(cfg, partition="balanced",
                                   overlap=False), **kw)
        np.testing.assert_array_equal(r_dense.seeds, both.run().seeds)
        # fused chain + fused selection on the balanced 2D layout: the
        # pad-column masks and partition offsets must not perturb either
        bal_unf = InfluenceEngine(
            g, dataclasses.replace(cfg, partition="balanced",
                                   fused_pipeline="off"), **kw)
        r_bal_unf = bal_unf.run()
        np.testing.assert_array_equal(r_bal.seeds, r_bal_unf.seeds)
        np.testing.assert_array_equal(r_bal.counter, r_bal_unf.counter)
        np.testing.assert_array_equal(
            bal.select(5, method="fused-rebuild").seeds,
            bal.select(5, method="rebuild").seeds)
        np.testing.assert_array_equal(
            bal.select(5, method="fused-decrement").seeds,
            bal.select(5, method="decrement").seeds)
    noov = InfluenceEngine(
        g, dataclasses.replace(cfg, overlap=False), **kw)
    r_noov = noov.run()
    np.testing.assert_array_equal(r_dense.seeds, r_noov.seeds)
    np.testing.assert_array_equal(r_dense.counter, r_noov.counter)

    # --- fused membership queries agree --------------------------------
    queries = [r_dense.seeds[:2], r_dense.seeds]
    np.testing.assert_allclose(
        dense.influences(queries), sharded.influences(queries), rtol=1e-6)

    # --- snapshot/restore across mesh layouts ---------------------------
    with tempfile.TemporaryDirectory() as d:
        sharded.snapshot(d)
        on1d = InfluenceEngine(
            g, cfg, **mesh_engine_kwargs(make_im_mesh(n_dev)))
        assert on1d.restore(d)
        np.testing.assert_array_equal(on1d.select(5).seeds, r_dense.seeds)
        on1 = InfluenceEngine(g, cfg, mesh=jax.make_mesh((1,), ("data",)))
        assert on1.restore(d)
        np.testing.assert_array_equal(on1.select(5).seeds, r_dense.seeds)
        flat = InfluenceEngine(g, cfg)
        assert flat.restore(d)
        # meshless restore keeps the configured at-rest format
        assert flat.store.representation == want_rep
        if args.store == "auto":
            assert isinstance(flat.store, BitmapStore)
        np.testing.assert_array_equal(flat.select(5).seeds, r_dense.seeds)
        # restored engines keep sampling from the snapshotted key stream,
        # identically to the dense engine
        flat.extend(flat.theta + 64)
        back = InfluenceEngine(g, cfg, **kw)
        assert back.restore(d)
        back.extend(back.theta + 64)
        dense.extend(dense.theta + 64)
        np.testing.assert_array_equal(
            np.asarray(dense.store.counter), np.asarray(back.store.counter))
        np.testing.assert_array_equal(
            np.asarray(dense.store.counter), np.asarray(flat.store.counter))

    print(json.dumps({
        "ok": True, "devices": n_dev, "mesh": args.mesh,
        "store": args.store,
        "theta": int(r_sharded.theta),
        "cap_local": int(st.cap_local), "n_local": int(st.n_local),
        "counts": [int(c) for c in st.counts],
        "imbalance": imb,
    }))


if __name__ == "__main__":
    sys.exit(main())
