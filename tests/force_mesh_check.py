"""Subprocess body for the forced multi-device ShardedStore checks.

Run by tests/test_sharded_store.py with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` so the mesh code
paths execute on real (host-platform) multi-device buffers even on
CPU-only runners.  Asserts the C1 acceptance criteria:

  * ``ShardedStore.extend`` never materializes the full arena on one
    device (per-shard buffer shapes are ``(cap_local, n)``);
  * sharded ``select(k)`` is seed-for-seed identical to ``BitmapStore`` +
    dense selection for a fixed ``cfg.seed``, including the true
    decremental sharded strategy;
  * snapshot/restore round-trips across mesh shapes (4 -> 1 -> none)
    without changing answers.

Prints one JSON line on success (consumed by the pytest wrapper).
"""
import json
import sys
import tempfile

import numpy as np
import jax

from repro.core.engine import InfluenceEngine, IMMConfig
from repro.core.store import BitmapStore, ShardedStore
from repro.graphs import rmat_graph


def main():
    n_dev = jax.device_count()
    assert n_dev == 4, f"expected 4 forced host devices, got {n_dev}"

    g = rmat_graph(128, 1024, seed=4)
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3)
    mesh = jax.make_mesh((4,), ("data",))

    dense = InfluenceEngine(g, cfg)
    sharded = InfluenceEngine(g, cfg, mesh=mesh)
    assert isinstance(dense.store, BitmapStore)
    assert isinstance(sharded.store, ShardedStore)

    r_dense, r_sharded = dense.run(), sharded.run()

    # --- seed-for-seed identity with BitmapStore + dense selection ------
    np.testing.assert_array_equal(r_dense.seeds, r_sharded.seeds)
    np.testing.assert_array_equal(r_dense.counter, r_sharded.counter)
    assert r_dense.theta == r_sharded.theta
    assert abs(r_dense.covered_frac - r_sharded.covered_frac) < 1e-7

    # --- the full arena never exists on one device ----------------------
    st = sharded.store
    shards = st.R.addressable_shards
    assert len(shards) == 4
    assert all(s.data.shape == (st.cap_local, g.n) for s in shards), \
        [s.data.shape for s in shards]
    assert st.capacity == 4 * st.cap_local
    assert {tuple(s.data.shape) for s in st.sizes.addressable_shards} == \
        {(st.cap_local,)}
    # counter partials are sharded too (one (1, n) block per device)
    assert all(s.data.shape == (1, g.n)
               for s in st._counter.addressable_shards)

    # --- true decremental sharded strategy == rebuild == dense ----------
    sel_reb = sharded.select(5, method="rebuild")
    sel_dec = sharded.select(5, method="decrement")
    np.testing.assert_array_equal(sel_reb.seeds, sel_dec.seeds)
    np.testing.assert_array_equal(sel_reb.gains, sel_dec.gains)
    np.testing.assert_array_equal(
        sel_dec.seeds, dense.select(5, method="decrement").seeds)

    # --- fused membership queries agree --------------------------------
    queries = [r_dense.seeds[:2], r_dense.seeds]
    np.testing.assert_allclose(
        dense.influences(queries), sharded.influences(queries), rtol=1e-6)

    # --- snapshot/restore across mesh shapes ---------------------------
    with tempfile.TemporaryDirectory() as d:
        sharded.snapshot(d)
        on1 = InfluenceEngine(g, cfg, mesh=jax.make_mesh((1,), ("data",)))
        assert on1.restore(d)
        np.testing.assert_array_equal(on1.select(5).seeds, r_dense.seeds)
        flat = InfluenceEngine(g, cfg)
        assert flat.restore(d)
        assert isinstance(flat.store, BitmapStore)
        np.testing.assert_array_equal(flat.select(5).seeds, r_dense.seeds)
        # restored engines keep sampling from the snapshotted key stream,
        # identically to the dense engine
        flat.extend(flat.theta + 64)
        on4 = InfluenceEngine(g, cfg, mesh=mesh)
        assert on4.restore(d)
        on4.extend(on4.theta + 64)
        dense.extend(dense.theta + 64)
        np.testing.assert_array_equal(
            np.asarray(dense.store.counter), np.asarray(on4.store.counter))
        np.testing.assert_array_equal(
            np.asarray(dense.store.counter), np.asarray(flat.store.counter))

    print(json.dumps({
        "ok": True, "devices": n_dev, "theta": int(r_sharded.theta),
        "cap_local": int(st.cap_local),
        "counts": [int(c) for c in st.counts],
    }))


if __name__ == "__main__":
    sys.exit(main())
