"""InfluenceEngine + RRRStore API: wrapper/engine equivalence, store growth
invariants, multi-query determinism, snapshot/restore, registries."""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.engine import InfluenceEngine, IMMConfig, Selection
from repro.core.imm import imm
from repro.core.sampler import (
    default_sampler_name, get_sampler, register_sampler, registered_samplers,
)
from repro.core.selection import get_selection, register_selection
from repro.core.store import (
    BitmapStore, IndexStore, MIN_CAPACITY, make_store, next_pow2,
    store_from_state,
)
from repro.graphs import path_graph, rmat_graph


def _random_batches(rng, n, batches, batch):
    out = []
    for _ in range(batches):
        out.append((rng.random((batch, n)) < 0.2).astype(np.uint8))
    return out


# ------------------------------------------------------------------ store ----

@pytest.mark.parametrize("kind", ["bitmap", "indices"])
def test_store_growth_preserves_counters_and_masks(kind):
    """Capacity doubling must not disturb counters, sizes, or valid rows."""
    rng = np.random.default_rng(0)
    n = 48
    store = make_store(kind, n)
    assert store.capacity == MIN_CAPACITY
    acc = np.zeros(n, np.int64)
    all_rows = []
    for batch in _random_batches(rng, n, batches=5, batch=24):
        store.add_batch(jnp.asarray(batch))
        acc += batch.sum(axis=0, dtype=np.int64)
        all_rows.append(batch)
    R_ref = np.concatenate(all_rows)
    assert store.count == 120
    assert store.capacity == next_pow2(120) == 128
    # fused counter survived every realloc
    np.testing.assert_array_equal(np.asarray(store.counter), acc)
    np.testing.assert_array_equal(
        np.asarray(store.sizes)[:120], R_ref.sum(axis=1))
    assert np.asarray(store.sizes)[120:].sum() == 0
    view = store.view()
    assert view.count == 120 and view.R.shape[0] == 128
    np.testing.assert_array_equal(
        np.asarray(view.valid), np.arange(128) < 120)
    # stored membership matches the raw batches
    if kind == "bitmap":
        np.testing.assert_array_equal(np.asarray(view.R)[:120], R_ref)
    else:
        got = np.asarray(view.R)[:120]
        for i in range(120):
            np.testing.assert_array_equal(
                np.unique(got[i][got[i] < n]), np.flatnonzero(R_ref[i]))


def test_index_store_widens_l_pad():
    n = 64
    store = IndexStore(n)
    small = np.zeros((4, n), np.uint8)
    small[:, :3] = 1
    store.add_batch(jnp.asarray(small))
    l0 = store.l_pad
    big = np.zeros((4, n), np.uint8)
    big[:, :20] = 1
    store.add_batch(jnp.asarray(big))
    assert store.l_pad == next_pow2(20, 4) > l0
    got = np.asarray(store.view().R)
    # earlier rows keep their meaning after widening (backfilled sentinel)
    np.testing.assert_array_equal(got[0][got[0] < n], np.arange(3))
    np.testing.assert_array_equal(got[4][got[4] < n], np.arange(20))


@pytest.mark.parametrize("kind", ["bitmap", "indices"])
def test_store_hits_matches_numpy(kind):
    rng = np.random.default_rng(1)
    n = 40
    store = make_store(kind, n)
    R = (rng.random((32, n)) < 0.15).astype(np.uint8)
    store.add_batch(jnp.asarray(R))
    S = np.asarray([[0, 1, 2], [5, 5, 5], [7, 30, 12]], np.int32)
    got = np.asarray(store.hits(S))
    ref = np.asarray([(R[:, s].any(axis=1)).mean() for s in S])
    np.testing.assert_allclose(got, ref, rtol=1e-6)


@pytest.mark.parametrize("kind", ["bitmap", "indices"])
def test_store_state_roundtrip(kind):
    rng = np.random.default_rng(2)
    store = make_store(kind, 32)
    store.add_batch(jnp.asarray((rng.random((20, 32)) < 0.3).astype(np.uint8)))
    clone = store_from_state(store.state())
    assert type(clone) is type(store)
    assert clone.count == store.count and clone.capacity == store.capacity
    np.testing.assert_array_equal(np.asarray(clone.R), np.asarray(store.R))
    np.testing.assert_array_equal(
        np.asarray(clone.counter), np.asarray(store.counter))


# ----------------------------------------------------------------- engine ----

@pytest.mark.parametrize("model", ["IC", "LT"])
@pytest.mark.parametrize("seed", [0, 7])
def test_imm_wrapper_reproduces_engine_seed_for_seed(model, seed):
    """The back-compat wrapper and an explicit engine must emit identical
    seeds, theta, and coverage for a fixed PRNG key."""
    g = rmat_graph(192, 1536, seed=2)
    cfg = IMMConfig(k=4, model=model, batch=128, max_theta=512, seed=seed)
    r1 = imm(g, cfg)
    r2 = InfluenceEngine(g, cfg).run()
    np.testing.assert_array_equal(r1.seeds, r2.seeds)
    assert r1.theta == r2.theta
    assert r1.covered_frac == pytest.approx(r2.covered_frac)
    np.testing.assert_array_equal(r1.counter, r2.counter)


def test_engine_multi_query_without_resampling():
    """>= 2 successive select(k) calls answer from one sampled store."""
    g = rmat_graph(256, 2048, seed=1)
    engine = InfluenceEngine(g, IMMConfig(k=8, batch=128, max_theta=1024))
    engine.run()
    theta = engine.theta
    a = engine.select(5)
    b = engine.select(5)
    c = engine.select(8)
    assert engine.theta == theta                  # no re-sampling happened
    assert a is b                                 # memoized
    np.testing.assert_array_equal(a.seeds, b.seeds)
    np.testing.assert_array_equal(a.seeds, c.seeds[:5])
    assert a.influence <= c.influence + 1e-6


def test_engine_extend_is_idempotent_and_monotone():
    g = rmat_graph(128, 1024, seed=3)
    engine = InfluenceEngine(g, IMMConfig(batch=64))
    assert engine.extend(100) >= 100
    got = engine.theta
    assert engine.extend(50) == got               # already satisfied
    assert engine.extend(got + 1) >= got + 1


def test_engine_influence_consistent_with_selection():
    g = rmat_graph(256, 2048, seed=1)
    engine = InfluenceEngine(g, IMMConfig(k=5, batch=128, max_theta=512))
    engine.extend(512)
    sel = engine.select(5)
    assert engine.influence(sel.seeds) == pytest.approx(sel.influence, rel=1e-6)
    vals = engine.influences([sel.seeds[:1], sel.seeds[:3], sel.seeds])
    assert vals[0] <= vals[1] <= vals[2] + 1e-9   # monotone in |S|
    with pytest.raises(ValueError):
        engine.influence([])
    with pytest.raises(ValueError):
        engine.influence([g.n + 5])


def test_engine_snapshot_restore_roundtrip():
    g = rmat_graph(200, 1600, seed=5)
    cfg = IMMConfig(k=4, batch=64, max_theta=512, seed=9)
    engine = InfluenceEngine(g, cfg)
    engine.run()
    want = engine.select(4)
    with tempfile.TemporaryDirectory() as d:
        assert engine.snapshot(d) is not None
        fresh = InfluenceEngine(g, cfg)
        assert fresh.restore(d)
        assert fresh.theta == engine.theta
        got = fresh.select(4)
        np.testing.assert_array_equal(got.seeds, want.seeds)
        # restored engines keep sampling from the snapshotted key stream
        fresh.extend(fresh.theta + 64)
        assert fresh.theta == engine.theta + 64
        # restore into a mismatched problem is refused
        other = InfluenceEngine(rmat_graph(64, 256, seed=0), cfg)
        with pytest.raises(ValueError):
            other.restore(d)


def test_engine_restore_returns_false_when_empty():
    g = rmat_graph(64, 256, seed=0)
    with tempfile.TemporaryDirectory() as d:
        assert not InfluenceEngine(g, IMMConfig()).restore(d)


def test_engine_index_store_backend_end_to_end():
    """The sparse-native arena answers the same API (seeds may differ from
    the dense backend only via float argmax ties)."""
    g = path_graph(512, p=0.5)
    engine = InfluenceEngine(
        g, IMMConfig(k=4, batch=64, max_theta=256, store="indices"))
    res = engine.run()
    assert res.representation == "indices"
    assert len(set(res.seeds.tolist())) == 4
    assert engine.influence(res.seeds) == pytest.approx(res.influence, rel=1e-6)


def test_native_index_emission_matches_bitmap_and_caps_width():
    """IndexStore + sparse backend emits lists natively (C4 routed
    per-backend): same seed -> identical counters/selections as the
    bitmap arena, with the emission width capped at exactly n (not the
    next power of two — top_k cannot exceed the bitmap minor dim) even
    when dense reachability fills every row on a non-pow2 n."""
    g = rmat_graph(100, 3000, seed=0)          # dense sets, n not pow2
    kw = dict(k=4, batch=16, max_theta=128, seed=1, backend="sparse")
    ei = InfluenceEngine(g, IMMConfig(store="indices", **kw))
    eb = InfluenceEngine(g, IMMConfig(store="bitmap", **kw))
    assert ei._emit_l > 0                      # native emission engaged
    ei.extend(64)
    eb.extend(64)
    assert ei._emit_l <= g.n
    np.testing.assert_array_equal(np.asarray(ei.store.counter),
                                  np.asarray(eb.store.counter))
    np.testing.assert_array_equal(ei.select(4).seeds, eb.select(4).seeds)


def test_restore_across_store_kinds_resets_index_emission():
    """Snapshots are elastic across store kinds: an indices-configured
    engine restoring a bitmap snapshot must drop native index emission,
    or its next extend would call add_index_batch on a BitmapStore."""
    g = rmat_graph(100, 3000, seed=0)
    kw = dict(k=4, batch=16, max_theta=128, seed=1, backend="sparse")
    src = InfluenceEngine(g, IMMConfig(store="bitmap", **kw))
    src.extend(32)
    with tempfile.TemporaryDirectory() as d:
        src.snapshot(d)
        idx = InfluenceEngine(g, IMMConfig(store="indices", **kw))
        assert idx._emit_l > 0
        assert idx.restore(d)
        assert isinstance(idx.store, BitmapStore) and idx._emit_l == 0
        idx.extend(64)                         # bitmap write path, no crash
        src.extend(64)
        np.testing.assert_array_equal(np.asarray(idx.store.counter),
                                      np.asarray(src.store.counter))


# ------------------------------------------------------------- registries ----

def test_sampler_registry_resolves_and_rejects():
    g = rmat_graph(64, 256, seed=0)
    assert default_sampler_name(g, IMMConfig(model="IC")) == "IC/dense"
    assert default_sampler_name(
        g, IMMConfig(model="IC", dense_sampler_max_n=8)) == "IC/sparse"
    assert default_sampler_name(g, IMMConfig(model="LT")) == "LT/walk"
    assert default_sampler_name(
        g, IMMConfig(model="WC", stable=True)) == "WC/dense+stable"
    assert default_sampler_name(
        g, IMMConfig(model="GT", backend="pallas")) == "GT/pallas"
    # canonical matrix names and deprecated legacy aliases all resolve
    assert {"IC/dense", "WC/sparse", "GT/pallas+stable", "LT/walk",
            "IC-dense", "IC-sparse", "LT"} <= set(registered_samplers())
    with pytest.raises(ValueError):
        get_sampler("no-such-sampler")
    with pytest.raises(ValueError):
        default_sampler_name(g, IMMConfig(model="SIR"))


def test_custom_sampler_plugs_into_engine():
    g = rmat_graph(64, 256, seed=0)

    @register_sampler("test-root-only")
    def _factory(graph, cfg):
        def sample(key):
            roots = jax.random.randint(key, (cfg.batch,), 0, graph.n)
            visited = jax.nn.one_hot(roots, graph.n, dtype=jnp.uint8)
            return visited, visited.sum(0).astype(jnp.int32), roots
        return sample

    engine = InfluenceEngine(
        g, IMMConfig(k=2, batch=32, max_theta=64, sampler="test-root-only"))
    engine.extend(64)
    sel = engine.select(2)
    assert engine.theta == 64 and len(sel.seeds) == 2


def test_selection_registry_covers_matrix_and_rejects():
    for method in ("rebuild", "decrement"):
        for layout in ("dense", "sparse", "sharded"):
            assert callable(get_selection(method, layout))
    with pytest.raises(ValueError):
        get_selection("rebuild", "no-such-layout")


def test_sharded_strategy_through_engine_matches_local():
    """Sharded selection via the strategy interface == local selection."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    g = rmat_graph(128, 1024, seed=4)
    cfg = IMMConfig(k=5, batch=64, max_theta=256)
    local = InfluenceEngine(g, cfg)
    sharded = InfluenceEngine(g, cfg, mesh=mesh, theta_axes=("data",))
    local.extend(256)
    sharded.extend(256)
    a = local.select(5)
    b = sharded.select(5)
    np.testing.assert_array_equal(a.seeds, b.seeds)
    assert a.covered_frac == pytest.approx(b.covered_frac)
