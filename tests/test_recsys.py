"""FM recsys: sum-square trick, retrieval decomposition, sharded lookup."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip on clean machines
from hypothesis import given, settings, strategies as st

from repro.models.recsys.fm import (
    FMConfig, init_fm, fm_logits, fm_loss, fm_retrieval_scores,
)

settings.register_profile("ci2", deadline=None, max_examples=20)
settings.load_profile("ci2")

CFG = FMConfig(n_sparse=5, embed_dim=4, vocab_per_field=50)


@given(st.integers(0, 10_000))
def test_fm_sum_square_trick_vs_pairwise(seed):
    v = jax.random.normal(jax.random.PRNGKey(seed), (3, 6, 4)) * 0.5
    s = v.sum(1)
    trick = 0.5 * ((s * s) - (v * v).sum(1)).sum(-1)
    inner = jnp.einsum("bik,bjk->bij", v, v)
    iu = jnp.triu_indices(6, k=1)
    pairwise = inner[:, iu[0], iu[1]].sum(-1)
    np.testing.assert_allclose(np.asarray(trick), np.asarray(pairwise),
                               rtol=1e-4, atol=1e-5)


def test_fm_logits_shape_and_grad():
    p = init_fm(jax.random.PRNGKey(0), CFG)
    idx = jax.random.randint(jax.random.PRNGKey(1), (16, 5), 0, 50)
    labels = (jax.random.uniform(jax.random.PRNGKey(2), (16,)) < 0.5
              ).astype(jnp.float32)
    logits = fm_logits(p, CFG, idx)
    assert logits.shape == (16,)
    loss, grads = jax.value_and_grad(fm_loss)(p, CFG, idx, labels)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.isfinite(g).all()) for g in jax.tree.leaves(grads))


def test_fm_retrieval_decomposition_matches_full_logit():
    """score(c) - score(c') must equal logit(u+c) - logit(u+c') when the
    candidate field is appended (self-interaction of a single one-hot
    candidate is zero, so the decomposition is exact up to a shared
    constant)."""
    cfg = FMConfig(n_sparse=5, embed_dim=4, vocab_per_field=50)
    p = init_fm(jax.random.PRNGKey(0), cfg)
    user = jnp.array([3, 7, 11, 19], jnp.int32)      # 4 user fields
    # treat field 4 as the candidate field
    cands = jnp.array([0, 1, 2], jnp.int32)
    cand_rows = cands + 4 * cfg.vocab_per_field
    scores = fm_retrieval_scores(p, cfg, user, cand_rows)
    full = []
    for c in [0, 1, 2]:
        idx = jnp.concatenate([user, jnp.array([c])])[None, :]
        full.append(float(fm_logits(p, cfg, idx)[0]))
    diffs_fast = np.diff(np.asarray(scores))
    diffs_full = np.diff(np.array(full))
    np.testing.assert_allclose(diffs_fast, diffs_full, rtol=1e-4, atol=1e-5)


def test_fm_loss_decreases_with_training():
    from repro.data.clicks import synthetic_click_batches
    from repro.optim import AdamWConfig, adamw_init, adamw_update

    cfg = FMConfig(n_sparse=4, embed_dim=4, vocab_per_field=32)
    p = init_fm(jax.random.PRNGKey(0), cfg)
    opt_cfg = AdamWConfig(lr=0.05, weight_decay=0.0)
    opt = adamw_init(p, opt_cfg)

    @jax.jit
    def step(p, opt, idx, labels):
        loss, grads = jax.value_and_grad(fm_loss)(p, cfg, idx, labels)
        p, opt = adamw_update(p, grads, opt, opt_cfg)
        return p, opt, loss

    losses = []
    for idx, labels in synthetic_click_batches(4, 32, 256, 60, seed=1):
        p, opt, loss = step(p, opt, jnp.asarray(idx), jnp.asarray(labels))
        losses.append(float(loss))
    assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.02
