"""Streaming subsystem: GraphDelta semantics, reverse-touch invalidation,
StreamEngine refresh equivalence (the headline invariant), bounded-memory
eviction/compaction, snapshot provenance, and IMServer epoch-consistent
serving.

Mesh-touching tests use however many devices the process has — 1 in a
plain run, 4 under scripts/ci.sh's forced-4-device pass, where the
per-shard eviction/compaction paths run with real multi-device buffers.
"""
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.core.store import (
    BitmapStore, IndexStore, ShardedStore, StorePressurePolicy, make_store,
    store_from_state,
)
from repro.graphs import rmat_graph
from repro.graphs.csr import build_graph, dense_ic_matrix, edge_arrays
from repro.launch.serve import IMServer
from repro.stream import (
    GraphDelta, StreamEngine, canonicalize, invalidate, random_delta,
    rows_touching,
)


def theta_mesh():
    return jax.make_mesh((jax.device_count(),), ("data",))


def small_graph(seed=2):
    return rmat_graph(96, 768, seed=seed)


# ------------------------------------------------------------- GraphDelta ----

def test_delta_apply_matches_dense_matrix():
    """CSR rebuild and dense-matrix scatter agree edge-for-edge."""
    g = canonicalize(small_graph())
    rng = np.random.default_rng(0)
    d = random_delta(g, rng, inserts=5, deletes=4, reweights=3)
    g2 = d.apply(g)
    P2 = d.apply_dense(dense_ic_matrix(g))
    np.testing.assert_allclose(np.asarray(dense_ic_matrix(g2)),
                               np.asarray(P2), rtol=1e-6)
    assert g2.m == g.m + 5 - 4


def test_delta_untouched_edges_are_bit_identical():
    """Edges whose dst was not mutated keep exact probs and LT weights."""
    g = canonicalize(small_graph())
    rng = np.random.default_rng(1)
    d = random_delta(g, rng, inserts=2, deletes=2, reweights=2)
    g2 = d.apply(g)
    touched = set(d.touched_vertices().tolist())
    s1, d1, p1, w1 = edge_arrays(g)
    s2, d2, p2, w2 = edge_arrays(g2)
    e1 = {(int(u), int(v)): (p, w) for u, v, p, w in zip(s1, d1, p1, w1)}
    e2 = {(int(u), int(v)): (p, w) for u, v, p, w in zip(s2, d2, p2, w2)}
    for (u, v), (p, w) in e1.items():
        if v in touched or (u, v) not in e2:
            continue
        assert e2[(u, v)] == (p, w)
    # untouched dst segments keep bit-identical LT cum arrays and totals
    lt1 = np.asarray(g.in_lt_total)
    lt2 = np.asarray(g2.in_lt_total)
    for v in range(g.n):
        if v not in touched:
            assert lt1[v] == lt2[v]


def test_delta_strict_validation():
    g = canonicalize(small_graph())
    src = np.asarray(g.in_src)
    dst = np.asarray(g.edge_dst)
    with pytest.raises(ValueError, match="insert of existing"):
        GraphDelta.inserts([src[0]], [dst[0]], [0.5]).apply(g)
    absent_u, absent_v = 0, 1
    existing = set(zip(src.tolist(), dst.tolist()))
    while (absent_u, absent_v) in existing or absent_u == absent_v:
        absent_v += 1
    with pytest.raises(ValueError, match="delete of missing"):
        GraphDelta.deletes([absent_u], [absent_v]).apply(g)
    with pytest.raises(ValueError, match="reweight of missing"):
        GraphDelta.reweights([absent_u], [absent_v], [0.3]).apply(g)
    with pytest.raises(ValueError, match="out of range"):
        GraphDelta.inserts([0], [g.n + 3], [0.5]).apply(g)
    with pytest.raises(ValueError, match="probabilities"):
        GraphDelta.inserts([absent_u], [absent_v], [-0.5])
    with pytest.raises(ValueError, match="probabilities"):
        GraphDelta.reweights([src[0]], [dst[0]], [1.5])
    # insert-then-delete inside one delta cancels out
    d = GraphDelta.concat([
        GraphDelta.inserts([absent_u], [absent_v], [0.5]),
        GraphDelta.deletes([absent_u], [absent_v]),
    ])
    assert d.apply(g).m == g.m


def test_delta_lt_totals_stay_bounded():
    """Inserted LT weights keep every per-dst total < 1."""
    g = canonicalize(small_graph())
    rng = np.random.default_rng(3)
    for _ in range(3):
        g = random_delta(g, rng, inserts=8, reweights=4).apply(g)
    assert float(np.asarray(g.in_lt_total).max()) < 1.0


def test_canonicalize_is_idempotent():
    g = canonicalize(small_graph())
    g2 = canonicalize(g)
    for field in ("in_prob", "in_lt_cum", "in_lt_total", "in_src",
                  "edge_dst"):
        np.testing.assert_array_equal(np.asarray(getattr(g, field)),
                                      np.asarray(getattr(g2, field)))


@pytest.mark.parametrize("name", ["IC-dense-stable", "IC-sparse-stable",
                                  "LT-stable"])
def test_stable_samplers_regenerate_row_subsets_exactly(name):
    """positions=(...) re-generates exactly those rows of the batch —
    the hook that makes refresh work scale with stale rows."""
    from repro.core.sampler import bind_sampler, get_sampler
    g = canonicalize(small_graph())
    model = "LT" if name == "LT-stable" else "IC"
    cfg = IMMConfig(batch=32, model=model, sampler=name)
    fn = bind_sampler(get_sampler(name), g, cfg)
    key = jax.random.PRNGKey(5)
    full, _, roots = fn(key)
    pos = np.asarray([3, 17, 4, 31])
    sub, _, sub_roots = fn(key, positions=jnp.asarray(pos, jnp.int32))
    np.testing.assert_array_equal(np.asarray(sub), np.asarray(full)[pos])
    np.testing.assert_array_equal(np.asarray(sub_roots),
                                  np.asarray(roots)[pos])


# ----------------------------------------------------------- invalidation ----

@pytest.mark.parametrize("kind", ["bitmap", "indices"])
def test_rows_touching_matches_numpy(kind):
    rng = np.random.default_rng(4)
    n = 40
    store = make_store(kind, n)
    R = (rng.random((24, n)) < 0.2).astype(np.uint8)
    store.add_batch(jnp.asarray(R))
    verts = np.asarray([3, 17, 31])
    got = np.asarray(rows_touching(store, verts))[:24]
    np.testing.assert_array_equal(got, R[:, verts].any(axis=1))


def test_invalidate_drops_rows_from_serving_immediately():
    """Stale rows leave select/hits/counter with no rebuild."""
    rng = np.random.default_rng(5)
    n = 48
    store = BitmapStore(n)
    R = (rng.random((64, n)) < 0.25).astype(np.uint8)
    store.add_batch(jnp.asarray(R))
    verts = np.asarray([7, 11])
    stale = R[:, verts].any(axis=1)
    assert invalidate(store, verts) == int(stale.sum()) > 0
    assert store.live_count == 64 - int(stale.sum())
    np.testing.assert_array_equal(np.asarray(store.counter),
                                  R[~stale].sum(axis=0))
    # hits normalize over surviving rows only
    S = np.asarray([[0, 1]], np.int32)
    want = R[~stale][:, [0, 1]].any(axis=1).mean()
    assert float(store.hits(S)[0]) == pytest.approx(want)
    # view().valid excludes them, so any selection strategy skips them
    v = store.view()
    np.testing.assert_array_equal(np.asarray(v.valid)[:64], ~stale)


def test_invalidate_sharded_matches_single_device():
    rng = np.random.default_rng(6)
    n = 36
    bs, ss = BitmapStore(n), ShardedStore(n, mesh=theta_mesh())
    R = (rng.random((40, n)) < 0.25).astype(np.uint8)
    bs.add_batch(jnp.asarray(R))
    ss.add_batch(jnp.asarray(R))
    verts = np.asarray([1, 2, 3])
    assert invalidate(bs, verts) == invalidate(ss, verts)
    np.testing.assert_array_equal(np.asarray(bs.counter),
                                  np.asarray(ss.counter))
    assert bs.live_count == ss.live_count


# --------------------------------------------------- eviction / compaction ----

def test_pressure_policy_row_caps():
    assert StorePressurePolicy(max_rows=100).row_cap(64) == 100
    assert StorePressurePolicy(max_bytes=6400).row_cap(64) == 100
    assert StorePressurePolicy(max_rows=50, max_bytes=6400).row_cap(64) == 50
    assert StorePressurePolicy().row_cap(64) is None
    with pytest.raises(ValueError):
        StorePressurePolicy(max_bytes=10).row_cap(64)


def test_compact_preserves_live_rows_and_remaps():
    rng = np.random.default_rng(7)
    n = 32
    store = BitmapStore(n)
    store.track_remaps = True
    R = (rng.random((48, n)) < 0.3).astype(np.uint8)
    store.add_batch(jnp.asarray(R))
    dead = np.zeros(store.capacity, bool)
    dead[[3, 10, 40]] = True
    store.kill_rows(dead)
    remap = store.compact()
    assert store.count == 45 and store.dead == 0
    keep = ~dead[:48]
    np.testing.assert_array_equal(np.asarray(store.R)[:45], R[keep])
    # remap follows every surviving row to its new slot
    for old in np.flatnonzero(keep):
        np.testing.assert_array_equal(
            np.asarray(store.R)[remap[old]], R[old])
    assert all(remap[i] == -1 for i in (3, 10, 40))
    assert len(store.drain_remaps()) == 1 and not store.drain_remaps()


def test_eviction_is_staleness_first_then_fifo():
    """Under pressure, dead rows are reclaimed before any live row, and
    live victims go oldest-first."""
    rng = np.random.default_rng(8)
    n = 24
    store = BitmapStore(n, policy=StorePressurePolicy(max_rows=32))
    R = (rng.random((32, n)) < 0.4).astype(np.uint8)
    store.add_batch(jnp.asarray(R))
    dead = np.zeros(store.capacity, bool)
    dead[:8] = True
    store.kill_rows(dead)
    newer = (rng.random((8, n)) < 0.4).astype(np.uint8)
    store.add_batch(jnp.asarray(newer))       # fits exactly in freed slots
    assert store.capacity == 32 and store.count == 32
    np.testing.assert_array_equal(np.asarray(store.R)[:24], R[8:])
    np.testing.assert_array_equal(np.asarray(store.R)[24:], newer)
    # now no dead rows: the next batch evicts the *oldest* live rows
    extra = (rng.random((4, n)) < 0.4).astype(np.uint8)
    store.add_batch(jnp.asarray(extra))
    got = np.asarray(store.R)
    np.testing.assert_array_equal(got[:20], R[12:])
    np.testing.assert_array_equal(got[28:], extra)
    assert store.count == 32


def test_sharded_store_respects_cap_per_shard():
    """Per-shard buffer shapes never exceed the policy's per-shard share
    across repeated writes (the bounded-memory acceptance shape check)."""
    n = 24
    mesh = theta_mesh()
    store = ShardedStore(n, mesh=mesh, policy=StorePressurePolicy(max_rows=64))
    rng = np.random.default_rng(9)
    local_cap = 64 // store.D
    for _ in range(8):
        store.add_batch(jnp.asarray(
            (rng.random((16, n)) < 0.3).astype(np.uint8)))
        assert store.capacity <= 64
        assert store.cap_local <= local_cap
        # every per-device buffer is exactly (cap_local, n) — the cap
        # holds physically, shard by shard, not just as bookkeeping
        assert all(s.data.shape == (store.cap_local, n)
                   for s in store.R.addressable_shards)
    assert store.count <= 64 and store.live_count <= 64


def test_stream_extend_terminates_on_non_divisible_cap():
    """A cap that is not a multiple of the shard count must clamp to the
    attainable D*(cap//D) rows instead of hanging extend-to-cap loops."""
    g = small_graph()
    cfg = IMMConfig(k=3, batch=16, seed=0)
    stream = StreamEngine(g, cfg, mesh=theta_mesh(),
                          policy=StorePressurePolicy(max_rows=70))
    D = stream.store.D
    attainable = (70 // D) * D
    assert stream.store.row_cap == attainable
    assert stream.extend(100) == attainable
    assert stream.refresh() == 0


def test_index_store_lifecycle_roundtrip():
    """kill/replace/compact work on the index-list arena too."""
    rng = np.random.default_rng(10)
    n = 40
    store = IndexStore(n)
    R = (rng.random((16, n)) < 0.2).astype(np.uint8)
    store.add_batch(jnp.asarray(R))
    dead = np.zeros(store.capacity, bool)
    dead[[2, 5]] = True
    store.kill_rows(dead)
    np.testing.assert_array_equal(
        np.asarray(store.counter),
        np.delete(R, [2, 5], axis=0).sum(axis=0))
    repl = (rng.random((2, n)) < 0.5).astype(np.uint8)
    store.replace_rows(np.asarray([2, 5]), jnp.asarray(repl))
    want = R.copy()
    want[[2, 5]] = repl
    np.testing.assert_array_equal(np.asarray(store.counter), want.sum(0))
    assert store.live_count == 16


def test_snapshot_drops_stale_rows():
    """state()/restore round-trips live rows only, on both layouts."""
    rng = np.random.default_rng(11)
    n = 28
    R = (rng.random((20, n)) < 0.3).astype(np.uint8)
    for store in (BitmapStore(n), ShardedStore(n, mesh=theta_mesh())):
        slots = store.add_batch(jnp.asarray(R))
        dead = np.zeros(store.capacity, bool)
        dead[slots[[0, 7]]] = True            # batch rows 0 and 7
        store.kill_rows(dead)
        clone = store_from_state(store.state())
        assert clone.live_count == 18
        np.testing.assert_array_equal(np.asarray(clone.counter),
                                      np.asarray(store.counter))


# ------------------------------------------------- the headline invariant ----

def _assert_stream_equals_fresh(stream, cfg, k=5):
    # stream.cfg carries the delta-stable sampler upgrade; the fresh
    # reference must sample with the same registry entry
    fresh = InfluenceEngine(stream.graph, stream.cfg)
    fresh.extend(stream.theta)
    a, b = stream.select(k), fresh.select(k)
    np.testing.assert_array_equal(a.seeds, b.seeds)
    assert a.covered_frac == pytest.approx(b.covered_frac)
    np.testing.assert_array_equal(np.asarray(stream.store.counter),
                                  np.asarray(fresh.store.counter))
    np.testing.assert_allclose(
        stream.influences([a.seeds[:2], a.seeds]),
        fresh.influences([a.seeds[:2], a.seeds]), rtol=1e-6)


@pytest.mark.parametrize("sampler", ["IC-dense", "IC-sparse"])
def test_refresh_equivalence_single_device(sampler):
    """After any delta sequence, refreshing until stale == 0 serves
    exactly what a fresh engine on the post-delta graph would."""
    cfg = IMMConfig(k=5, batch=64, max_theta=512, seed=7, sampler=sampler)
    stream = StreamEngine(small_graph(), cfg)
    assert stream.cfg.sampler == f"{sampler}-stable"
    assert stream.engine.supports_row_resample
    stream.extend(256)
    rng = np.random.default_rng(12)
    for _ in range(3):                        # deltas without refresh between
        stream.apply_delta(random_delta(
            stream.graph, rng, inserts=3, deletes=3, reweights=2))
    assert stream.refresh() == 0 and stream.consistent
    _assert_stream_equals_fresh(stream, cfg)


@pytest.mark.parametrize("sampler", ["IC-dense", "IC-sparse"])
def test_refresh_equivalence_mesh(sampler):
    """Same invariant with the stream's store mesh-sharded; the fresh
    reference runs single-device (layout independence both ways)."""
    cfg = IMMConfig(k=5, batch=64, max_theta=512, seed=3, sampler=sampler)
    stream = StreamEngine(small_graph(), cfg, mesh=theta_mesh())
    assert isinstance(stream.store, ShardedStore)
    stream.extend(192)
    rng = np.random.default_rng(13)
    for _ in range(2):
        stream.apply_delta(random_delta(
            stream.graph, rng, inserts=2, deletes=2, reweights=2))
        stream.refresh()                      # refresh between deltas too
    assert stream.stale == 0
    _assert_stream_equals_fresh(stream, cfg)


@pytest.mark.slow
def test_refresh_equivalence_lt_model():
    """The LT walk re-samples stably through canonicalized rebuilds."""
    cfg = IMMConfig(k=4, batch=64, max_theta=512, seed=5, model="LT")
    stream = StreamEngine(small_graph(), cfg)
    stream.extend(256)
    rng = np.random.default_rng(14)
    for _ in range(3):
        stream.apply_delta(random_delta(
            stream.graph, rng, inserts=3, deletes=3, reweights=3))
    assert stream.refresh() == 0
    _assert_stream_equals_fresh(stream, cfg, k=4)


@pytest.mark.slow
def test_budgeted_refresh_converges_incrementally():
    """Row-budgeted refresh makes monotone progress and lands on the
    same fixed point as one unbudgeted refresh."""
    cfg = IMMConfig(k=4, batch=32, max_theta=512, seed=9)
    stream = StreamEngine(small_graph(), cfg)
    stream.extend(256)
    rng = np.random.default_rng(15)
    stream.apply_delta(random_delta(
        stream.graph, rng, inserts=4, deletes=4, reweights=4))
    backlog = stream.stale
    assert backlog > 0
    steps = 0
    while stream.stale:
        left = stream.refresh(budget=48)
        assert left <= backlog
        backlog = left
        steps += 1
        assert steps < 64
    _assert_stream_equals_fresh(stream, cfg, k=4)


def test_epoch_tags_and_memoization_invalidate_on_delta():
    cfg = IMMConfig(k=3, batch=32, max_theta=256, seed=1)
    stream = StreamEngine(small_graph(), cfg)
    stream.extend(128)
    a = stream.select(3)
    assert a.epoch == 0 and a.stale == 0
    rng = np.random.default_rng(16)
    stream.apply_delta(random_delta(stream.graph, rng, deletes=6))
    b = stream.select(3)
    assert b.epoch == 1 and b.stale > 0      # answered from survivors
    assert stream.theta < 128
    # memoization did not serve the pre-delta answer: the new epoch's
    # selection was recomputed against fewer (surviving) rows
    assert b.theta == stream.theta < a.theta
    stream.refresh()
    c = stream.select(3)
    assert c.epoch == 1 and c.stale == 0 and c.theta == 128
    # the repaired store answers sigma for the *current* graph — pin that
    # it can't echo the pre-delta memo entry by comparing against a fresh
    # engine on the post-delta graph
    fresh = InfluenceEngine(stream.graph, stream.cfg)
    fresh.extend(128)
    assert stream.influence(c.seeds) == pytest.approx(
        fresh.influence(c.seeds), rel=1e-6)


def test_bounded_stream_keeps_cap_and_quality():
    """10-delta stream under max_rows: capacity never exceeds the cap
    while selection quality stays within 2% of the unbounded store."""
    g = small_graph()
    cfg = IMMConfig(k=5, batch=64, max_theta=4096, seed=4)
    cap = 512
    bounded = StreamEngine(g, cfg, policy=StorePressurePolicy(max_rows=cap))
    unbounded = StreamEngine(g, cfg)
    bounded.extend(1024)                      # clamps to the cap
    unbounded.extend(1024)
    assert bounded.theta == cap
    rng_b, rng_u = (np.random.default_rng(17) for _ in range(2))
    for _ in range(10):
        d = random_delta(bounded.graph, rng_b, inserts=2, deletes=2,
                         reweights=2, max_dst_indeg=6)
        bounded.apply_delta(d)
        bounded.refresh()
        assert bounded.store.capacity <= cap
        assert np.asarray(bounded.store.R).shape[0] <= cap
        d2 = random_delta(unbounded.graph, rng_u, inserts=2, deletes=2,
                          reweights=2, max_dst_indeg=6)
        unbounded.apply_delta(d2)
        unbounded.refresh()
    # identical delta streams (same rng seed) => same final graph
    np.testing.assert_array_equal(np.asarray(bounded.graph.in_src),
                                  np.asarray(unbounded.graph.in_src))
    sb = bounded.select(5)
    su = unbounded.select(5)
    # judge both seed sets on the unbounded (higher-theta) estimator
    sigma_b, sigma_u = unbounded.influences([sb.seeds, su.seeds])
    assert sigma_b >= 0.98 * sigma_u


# ---------------------------------------------- snapshot provenance ----

def _layout_kwargs(side):
    """Engine keyword arguments for a snapshot-layout side: single
    device, a 1D theta mesh, or a 2D theta x vertex mesh."""
    if side == "flat":
        return {}
    if side == "mesh":
        return {"mesh": theta_mesh()}
    d = jax.device_count()
    return mesh_engine_kwargs(
        make_im_mesh((d // 2, 2) if d % 2 == 0 else (d, 1)))


@pytest.mark.parametrize("layouts", ["flat->flat", "mesh->mesh",
                                     "flat->mesh", "mesh->flat",
                                     "flat->2d", "2d->flat",
                                     "mesh->2d", "2d->2d"])
def test_stream_snapshot_restores_batch_key_provenance(layouts):
    """A restored stream same-key repairs instead of topping up: after
    snapshot/restore (across any store-layout pair, including onto and
    off a 2D theta x vertex mesh), a delta + refresh leaves the store
    seed-for-seed equal to the original stream's — and to a fresh engine
    on the post-delta graph."""
    src_kw, dst_kw = [_layout_kwargs(side)
                      for side in layouts.split("->")]
    g = small_graph()
    cfg = IMMConfig(k=4, batch=64, max_theta=512, seed=7)
    original = StreamEngine(g, cfg, **src_kw)
    original.extend(256)
    with tempfile.TemporaryDirectory() as d:
        original.snapshot(d)
        restored = StreamEngine(g, cfg, **dst_kw)
        assert restored.restore(d)
    assert restored.theta == 256 and restored.target_theta == 256
    filled = np.flatnonzero(restored._slot_batch >= 0)
    assert filled.size == 256          # every live row kept its provenance
    rng_a, rng_b = (np.random.default_rng(22) for _ in range(2))
    original.apply_delta(random_delta(original.graph, rng_a, inserts=3,
                                      deletes=3, reweights=2))
    restored.apply_delta(random_delta(restored.graph, rng_b, inserts=3,
                                      deletes=3, reweights=2))
    assert original.refresh() == 0 and restored.refresh() == 0
    a, b = original.select(4), restored.select(4)
    np.testing.assert_array_equal(a.seeds, b.seeds)
    np.testing.assert_array_equal(np.asarray(original.store.counter),
                                  np.asarray(restored.store.counter))
    _assert_stream_equals_fresh(restored, cfg, k=4)


def test_stream_snapshot_keeps_dead_row_provenance_single_device():
    """A single-device snapshot taken mid-repair (stale rows resident)
    restores the dead rows' provenance too, so the restored stream
    finishes the same-key repair the saved one had pending."""
    g = small_graph()
    cfg = IMMConfig(k=4, batch=64, max_theta=512, seed=9)
    stream = StreamEngine(g, cfg)
    stream.extend(256)
    rng = np.random.default_rng(23)
    stream.apply_delta(random_delta(stream.graph, rng, inserts=2,
                                    deletes=2, reweights=2))
    assert stream.stale > 0
    with tempfile.TemporaryDirectory() as d:
        stream.snapshot(d)
        restored = StreamEngine(stream.graph, cfg)
        assert restored.restore(d)
    assert restored.stale == stream.stale and restored.epoch == 1
    assert restored.refresh() == 0
    _assert_stream_equals_fresh(restored, cfg, k=4)


def test_stream_restore_returns_false_when_empty():
    g = small_graph()
    with tempfile.TemporaryDirectory() as d:
        assert not StreamEngine(g, IMMConfig(batch=32)).restore(d)


def test_stream_restore_rejects_mismatched_batch_or_sampler():
    """Saved batch keys only reproduce their rows under the identical
    sampler composition and batch width — a mismatched restore must fail
    loudly, not silently corrupt same-key repair."""
    g = small_graph()
    stream = StreamEngine(g, IMMConfig(batch=64, seed=1))
    stream.extend(128)
    with tempfile.TemporaryDirectory() as d:
        stream.snapshot(d)
        with pytest.raises(ValueError, match="batch"):
            StreamEngine(g, IMMConfig(batch=32, seed=1)).restore(d)
        with pytest.raises(ValueError, match="sampler"):
            StreamEngine(g, IMMConfig(batch=64, seed=1,
                                      backend="sparse")).restore(d)
        # ... and against the graph identity: resident rows sampled on
        # one edge set are not valid against another
        stream.apply_delta(random_delta(
            stream.graph, np.random.default_rng(24), deletes=2))
        with pytest.raises(ValueError, match="different graph"):
            StreamEngine(stream.graph,
                         IMMConfig(batch=64, seed=1)).restore(d)


# --------------------------------------------------------------- IMServer ----

def test_imserver_result_ordering_out_of_order_sizes():
    """Tickets map to their own answers under mixed seed-set sizes and
    multiple chunks (padding/batching never permutes results)."""
    g = small_graph()
    engine = InfluenceEngine(g, IMMConfig(k=4, batch=64, max_theta=256))
    engine.extend(256)
    server = IMServer(engine, max_batch=4)    # force several chunks
    rng = np.random.default_rng(18)
    sets = [rng.choice(g.n, size=s, replace=False)
            for s in (5, 1, 7, 2, 3, 1, 6, 4, 2, 5)]
    tickets = [server.submit(s) for s in sets]
    got = server.flush()
    assert server.pending == 0 and len(got) == len(sets)
    want = engine.influences(sets)
    for t, w in zip(tickets, want):
        assert got[t] == pytest.approx(float(w), rel=1e-6)


def test_imserver_background_refresh_epoch_consistency():
    """A flush spanning an apply_delta answers every ticket from one
    epoch (identical sets -> identical sigma), and the budgeted
    background refresh drains staleness between flushes."""
    g = small_graph()
    cfg = IMMConfig(k=4, batch=64, max_theta=512, seed=2)
    stream = StreamEngine(g, cfg)
    stream.extend(256)
    server = IMServer(stream, max_batch=4, refresh_budget=96)
    probe = np.asarray(stream.select(4).seeds)
    t0 = server.submit(probe)
    rng = np.random.default_rng(19)
    server.apply_delta(random_delta(stream.graph, rng, deletes=4,
                                    inserts=4))
    t1 = server.submit(probe)                 # same set, post-delta submit
    t2 = server.submit(probe)
    got = server.flush()
    # no torn read: all three answered against the same (post-delta) state
    assert got[t0] == got[t1] == got[t2]
    assert server.served_epoch == 1
    # background refresh drains between flushes without explicit calls
    for _ in range(32):
        if stream.stale == 0:
            break
        server.influence(probe)               # each flush repairs a slice
    assert stream.stale == 0
    # drained server answers == fresh engine on the current graph
    fresh = InfluenceEngine(stream.graph, stream.cfg)
    fresh.extend(stream.theta)
    assert server.influence(probe) == pytest.approx(
        fresh.influence(probe), rel=1e-6)


def test_imserver_async_refresh_worker_epoch_consistency():
    """The threaded refresh worker (ROADMAP: a true async IMServer
    queue): repair runs on a background thread *between* flushes, every
    flush stays epoch-consistent (identical sets in one flush ->
    identical sigma, no torn reads against the concurrent worker), the
    backlog drains with NO refresh calls from the serving path, and the
    drained store equals a fresh engine on the post-delta graph."""
    g = small_graph()
    cfg = IMMConfig(k=4, batch=64, max_theta=512, seed=3)
    stream = StreamEngine(g, cfg)
    stream.extend(256)
    with IMServer(stream, max_batch=4, refresh_budget=64,
                  async_refresh=True) as server:
        assert server.async_refreshing
        probe = np.asarray(server.select(4).seeds)
        rng = np.random.default_rng(20)
        for _ in range(3):            # several epochs under live repair
            t0 = server.submit(probe)
            server.apply_delta(random_delta(stream.graph, rng, deletes=3,
                                            inserts=3, reweights=2))
            t1 = server.submit(probe)
            t2 = server.submit(probe)
            got = server.flush()
            # one flush == one epoch: the worker cannot interleave a
            # repair slice (which would change sigma) mid-flush
            assert got[t0] == got[t1] == got[t2]
        # the worker alone drains the backlog — no refresh() from here
        assert server.drain(timeout=60.0)
        assert stream.stale == 0 and server.refreshes_run > 0
        fresh = InfluenceEngine(stream.graph, stream.cfg)
        fresh.extend(stream.theta)
        np.testing.assert_array_equal(np.asarray(stream.store.counter),
                                      np.asarray(fresh.store.counter))
        assert server.influence(probe) == pytest.approx(
            fresh.influence(probe), rel=1e-6)
    assert not server.async_refreshing        # context exit stopped it


def test_imserver_async_refresh_requires_budget():
    g = small_graph()
    stream = StreamEngine(g, IMMConfig(batch=32))
    with pytest.raises(ValueError, match="refresh_budget"):
        IMServer(stream, async_refresh=True)


def test_imserver_rejects_refresh_budget_on_static_engine():
    g = small_graph()
    engine = InfluenceEngine(g, IMMConfig(batch=32))
    with pytest.raises(ValueError, match="StreamEngine"):
        IMServer(engine, refresh_budget=64)
    server = IMServer(engine)
    with pytest.raises(ValueError, match="StreamEngine"):
        server.apply_delta(None)
    # a zero budget could never drain a backlog — refused up front
    stream = StreamEngine(g, IMMConfig(batch=32))
    with pytest.raises(ValueError, match=">= 1"):
        IMServer(stream, refresh_budget=0)
    with pytest.raises(ValueError, match=">= 1"):
        stream.refresh(budget=0)


# --------------------------------------------------- satellite: fail-fast ----

def test_index_store_mesh_fails_fast_with_workaround():
    """Mesh + indices is refused at construction and at snapshot restore
    with a message naming the supported (representation, mesh)
    combinations (used to fail late and obscurely at the first
    select)."""
    g = rmat_graph(48, 256, seed=0)
    with pytest.raises(ValueError, match="bitmap"):
        InfluenceEngine(g, IMMConfig(store="indices"), mesh=theta_mesh())
    idx = make_store("indices", 16)
    idx.add_batch(jnp.asarray(np.eye(4, 16, dtype=np.uint8)))
    # the restore matrix error is one coherent message naming every
    # supported combination, not a single bitmap-only hint
    with pytest.raises(ValueError, match=r"(?s)\(representation, mesh\)"
                                         r".*bitmap.*packed.*compressed"
                                         r".*indices.*without a mesh"):
        store_from_state(idx.state(), mesh=theta_mesh())
    with pytest.raises(ValueError, match="bitmap"):
        StreamEngine(g, IMMConfig(store="indices"), mesh=theta_mesh())
