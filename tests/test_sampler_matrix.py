"""DiffusionModel x TraversalBackend decomposition: legacy-name goldens
(seed-for-seed vs the pre-decomposition monolithic samplers), the
model x backend x stable equivalence matrix, WC/GT end-to-end, the Pallas
engine backend, pow2 edge padding, and the legacy deprecation contract.

Mesh-touching tests use however many devices the process has — 1 in a
plain run, 4 under scripts/ci.sh's forced-4-device pass.
"""
import dataclasses
import hashlib
import warnings

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
from repro.core.engine import IMMConfig, InfluenceEngine
from repro.core.imm import imm
from repro.core import sampler as smp
from repro.core.sampler import (
    CoinModel, bind_sampler, composed_name, get_sampler, make_sampler,
    sampler_matrix, stable_variant,
)
from repro.graphs import rmat_graph
from repro.stream import StreamEngine, random_delta


def theta_mesh():
    return jax.make_mesh((jax.device_count(),), ("data",))


def golden_graph():
    return rmat_graph(96, 768, seed=2)


def sha(*arrays):
    h = hashlib.sha256()
    for a in arrays:
        h.update(np.ascontiguousarray(np.asarray(a)).tobytes())
    return h.hexdigest()[:16]


# Captured from the pre-decomposition monolithic samplers (PR 3 tree,
# commit f8d237a) on golden_graph() with batch=64, key=PRNGKey(123);
# ":positions" rows are the stable twins re-generating rows [5, 63, 17, 4].
SAMPLER_GOLDENS = {
    "IC-dense": "e33cd00ea560ebe0",
    "IC-sparse": "269f71a6250cfef4",
    "LT": "a31ab9dc68c74a8a",
    "IC-dense-stable": "78c8ce68f1c9de59",
    "IC-dense-stable:positions": "bcb92c9a1759fc8e",
    "IC-sparse-stable": "dc28b6dc1a537b49",
    "IC-sparse-stable:positions": "0b9465ecf663970c",
    "LT-stable": "8a0404a69feea9d9",
    "LT-stable:positions": "ea2faa0ae86e5207",
}

# imm() driver goldens on rmat_graph(192, 1536, seed=2) with
# IMMConfig(k=4, batch=128, max_theta=512, seed=7) — same provenance.
IMM_GOLDENS = {
    "IC": {"seeds": [120, 93, 105, 111], "theta": 512,
           "covered_frac": 0.66015625, "counter_sha": "75d367b57aeffb2c"},
    "LT": {"seeds": [0, 16, 32, 64], "theta": 512,
           "covered_frac": 0.25, "counter_sha": "465eca013f54fe64"},
    # IC forced through the sparse backend (dense_sampler_max_n=8)
    "IC-sparse": {"seeds": [120, 93, 111, 139], "theta": 512,
                  "covered_frac": 0.673828125,
                  "counter_sha": "547725793498d7fe"},
}

LEGACY_TO_AXES = {
    "IC-dense": ("IC", "dense", False),
    "IC-sparse": ("IC", "sparse", False),
    "LT": ("LT", "walk", False),
    "IC-dense-stable": ("IC", "dense", True),
    "IC-sparse-stable": ("IC", "sparse", True),
    "LT-stable": ("LT", "walk", True),
}


# ------------------------------------------------- seed-for-seed goldens ----

@pytest.mark.parametrize("name", sorted(LEGACY_TO_AXES))
def test_legacy_name_matches_pre_refactor_golden(name):
    """Every legacy registry name still emits the exact pre-decomposition
    sample stream (visited bitmaps, fused counter, roots)."""
    g = golden_graph()
    model, backend, stable = LEGACY_TO_AXES[name]
    cfg = IMMConfig(batch=64, model="LT" if model == "LT" else "IC",
                    sampler=name)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        fn = bind_sampler(get_sampler(name), g, cfg)
    v, c, r = fn(jax.random.PRNGKey(123))
    assert sha(v, c, r) == SAMPLER_GOLDENS[name]
    if stable:
        pos = jnp.asarray([5, 63, 17, 4], jnp.int32)
        v2, c2, r2 = fn(jax.random.PRNGKey(123), positions=pos)
        assert sha(v2, c2, r2) == SAMPLER_GOLDENS[name + ":positions"]


@pytest.mark.parametrize("name", sorted(LEGACY_TO_AXES))
def test_legacy_name_equals_make_sampler_composition(name):
    """Legacy aliases resolve through the composed axes: the alias, the
    canonical registry name, and a direct make_sampler() factory all
    produce bitwise-identical batches."""
    g = golden_graph()
    model, backend, stable = LEGACY_TO_AXES[name]
    cfg = IMMConfig(batch=64, model=model)
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        legacy = bind_sampler(get_sampler(name), g, cfg)
    canonical = bind_sampler(
        get_sampler(composed_name(model, backend, stable)), g, cfg)
    composed = bind_sampler(make_sampler(model, backend, stable=stable),
                            g, cfg)
    key = jax.random.PRNGKey(123)
    outs = [f(key) for f in (legacy, canonical, composed)]
    for v, c, r in outs[1:]:
        np.testing.assert_array_equal(np.asarray(v), np.asarray(outs[0][0]))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(outs[0][1]))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(outs[0][2]))


@pytest.mark.parametrize("case", sorted(IMM_GOLDENS))
def test_imm_driver_matches_pre_refactor_golden(case):
    """The end-to-end imm() driver (default dispatch through the new
    composition) reproduces the pre-decomposition seeds/theta/counter."""
    g = rmat_graph(192, 1536, seed=2)
    cfg = IMMConfig(k=4, model="LT" if case == "LT" else "IC", batch=128,
                    max_theta=512, seed=7)
    if case == "IC-sparse":
        cfg = IMMConfig(k=4, model="IC", batch=128, max_theta=512, seed=7,
                        dense_sampler_max_n=8)
    res = imm(g, cfg)
    want = IMM_GOLDENS[case]
    assert [int(s) for s in res.seeds] == want["seeds"]
    assert res.theta == want["theta"]
    assert res.covered_frac == pytest.approx(want["covered_frac"],
                                             rel=1e-12)
    assert sha(res.counter) == want["counter_sha"]


def test_goldens_hold_on_mesh():
    """The same golden stream lands from a mesh-sharded engine: sampling
    placement changes layout, never results (1 shard in a plain run, 4
    under the forced-4-device CI pass)."""
    g = rmat_graph(192, 1536, seed=2)
    cfg = IMMConfig(k=4, model="IC", batch=128, max_theta=512, seed=7)
    res = InfluenceEngine(g, cfg, mesh=theta_mesh()).run()
    want = IMM_GOLDENS["IC"]
    assert [int(s) for s in res.seeds] == want["seeds"]
    assert sha(res.counter) == want["counter_sha"]


# -------------------------------------------- model x backend x stable ----

COIN_CELLS = [(m, s) for m in ("IC", "WC", "GT") for s in (False, True)]


@pytest.mark.parametrize("model,stable", COIN_CELLS)
def test_dense_and_pallas_backends_agree_bitwise(model, stable):
    """The pallas backend is the dense math executed by the fused
    kernel: off-TPU dispatch (jnp oracle) and forced interpret-mode
    (the real kernel through the Pallas interpreter) are both bitwise
    equal to the dense backend for every coin model."""
    g = golden_graph()
    key = jax.random.PRNGKey(3)
    cfg = IMMConfig(batch=32, model=model)
    cfg_i = IMMConfig(batch=32, model=model, pallas_interpret=True)
    dense = bind_sampler(get_sampler(composed_name(model, "dense", stable)),
                         g, cfg)
    oracle = bind_sampler(get_sampler(composed_name(model, "pallas", stable)),
                          g, cfg)
    kernel = bind_sampler(get_sampler(composed_name(model, "pallas", stable)),
                          g, cfg_i)
    vd, cd, rd = dense(key)
    for fn in (oracle, kernel):
        v, c, r = fn(key)
        np.testing.assert_array_equal(np.asarray(v), np.asarray(vd))
        np.testing.assert_array_equal(np.asarray(c), np.asarray(cd))
        np.testing.assert_array_equal(np.asarray(r), np.asarray(rd))


@pytest.mark.parametrize("model,stable", COIN_CELLS)
def test_dense_and_sparse_backends_agree_in_distribution(model, stable):
    """Dense (log-semiring) and sparse (per-edge coin) execution of one
    model draw different coin layouts but the same distribution: mean
    RRR-set size agrees."""
    g = rmat_graph(128, 1024, seed=3)
    cfg = IMMConfig(batch=1024, model=model)
    d = bind_sampler(get_sampler(composed_name(model, "dense", stable)),
                     g, cfg)
    s = bind_sampler(get_sampler(composed_name(model, "sparse", stable)),
                     g, cfg)
    vd, _, _ = d(jax.random.PRNGKey(0))
    vs, _, _ = s(jax.random.PRNGKey(1))
    m_d = float(np.asarray(vd).sum(1).mean())
    m_s = float(np.asarray(vs).sum(1).mean())
    assert m_d == pytest.approx(m_s, rel=0.15), (m_d, m_s)


@pytest.mark.parametrize("model,backend", sampler_matrix())
def test_stable_cells_regenerate_row_subsets_exactly(model, backend):
    """positions=(...) re-generates exactly those rows for EVERY cell of
    the matrix — the property streaming repair is built on."""
    g = golden_graph()
    cfg = IMMConfig(batch=32, model=model)
    fn = bind_sampler(get_sampler(composed_name(model, backend, True)),
                      g, cfg)
    key = jax.random.PRNGKey(5)
    full, _, roots = fn(key)
    pos = np.asarray([3, 17, 4, 31])
    sub, _, sub_roots = fn(key, positions=jnp.asarray(pos, jnp.int32))
    np.testing.assert_array_equal(np.asarray(sub), np.asarray(full)[pos])
    np.testing.assert_array_equal(np.asarray(sub_roots),
                                  np.asarray(roots)[pos])


@pytest.mark.parametrize("model,backend", sampler_matrix())
def test_matrix_cell_mesh_equals_single_device(model, backend):
    """Every matrix cell is layout-independent end-to-end: a mesh-backed
    engine selects the same seeds as a single-device one (runs with 4
    real shards under scripts/ci.sh's forced-4-device pass)."""
    g = golden_graph()
    cfg = IMMConfig(k=3, batch=64, max_theta=128, seed=1, model=model,
                    backend=backend)
    local = InfluenceEngine(g, cfg)
    sharded = InfluenceEngine(g, cfg, mesh=theta_mesh())
    local.extend(128)
    sharded.extend(128)
    np.testing.assert_array_equal(np.asarray(local.store.counter),
                                  np.asarray(sharded.store.counter))
    a, b = local.select(3), sharded.select(3)
    np.testing.assert_array_equal(a.seeds, b.seeds)
    assert a.covered_frac == pytest.approx(b.covered_frac)


@pytest.mark.parametrize("model,backend", sampler_matrix())
def test_matrix_cell_2d_layouts_equal_single_device(model, backend):
    """Every matrix cell is invariant to the 2D vertex-column layout and
    the traversal schedule: edge-balanced blocks, overlap-off, and both
    at once all select bitwise the same seeds and counters as the
    single-device run (real theta x vertex tiles under scripts/ci.sh's
    forced-4-device pass — a 2x2 mesh there, 1x1 in a plain run)."""
    g = golden_graph()
    cfg = IMMConfig(k=3, batch=64, max_theta=128, seed=1, model=model,
                    backend=backend)
    local = InfluenceEngine(g, cfg)
    local.extend(128)
    ref_counter = np.asarray(local.store.counter)
    ref = local.select(3)
    d = jax.device_count()
    mesh = make_im_mesh((d // 2, 2) if d % 2 == 0 else (d, 1))
    kw = mesh_engine_kwargs(mesh)
    for variant in ({"partition": "balanced"}, {"overlap": False},
                    {"partition": "balanced", "overlap": False}):
        e = InfluenceEngine(g, dataclasses.replace(cfg, **variant), **kw)
        e.extend(128)
        np.testing.assert_array_equal(ref_counter,
                                      np.asarray(e.store.counter))
        sel = e.select(3)
        np.testing.assert_array_equal(ref.seeds, sel.seeds)
        assert ref.covered_frac == pytest.approx(sel.covered_frac)


def test_family_mismatch_fails_fast():
    with pytest.raises(ValueError, match="family"):
        make_sampler("LT", "dense")
    with pytest.raises(ValueError, match="family"):
        make_sampler("IC", "walk")
    # the config path fails with the same explanation, not a generic
    # unknown-sampler error at resolution time
    from repro.core.sampler import default_sampler_name
    with pytest.raises(ValueError, match="family"):
        default_sampler_name(golden_graph(),
                             IMMConfig(model="IC", backend="walk"))
    with pytest.raises(ValueError, match="unknown diffusion model"):
        make_sampler("SIR")
    with pytest.raises(ValueError, match="unknown traversal backend"):
        make_sampler("IC", "fpga")


def test_positional_cells_reject_positions():
    g = golden_graph()
    fn = bind_sampler(make_sampler("IC", "dense"), g, IMMConfig(batch=16))
    with pytest.raises(TypeError):
        fn(jax.random.PRNGKey(0), positions=jnp.asarray([0, 1], jnp.int32))


def test_post_import_model_resolves_through_config_path():
    """register_model alone is enough: the composed canonical names
    resolve on demand (engine config path, stable upgrade) with no
    explicit register_sampler calls."""
    from repro.core.sampler import register_model
    register_model(CoinModel("flat-post", lambda g: jnp.full(
        (g.m,), 0.1, jnp.float32)))
    g = golden_graph()
    engine = InfluenceEngine(
        g, IMMConfig(model="flat-post", k=2, batch=32, max_theta=64))
    assert engine.sampler_name == "flat-post/dense"
    engine.extend(64)
    assert len(engine.select(2).seeds) == 2
    assert stable_variant("flat-post/sparse") == "flat-post/sparse+stable"
    stream = StreamEngine(g, IMMConfig(model="flat-post", batch=32))
    assert stream.cfg.sampler == "flat-post/dense+stable"
    assert stream.engine.supports_row_resample
    with pytest.raises(ValueError, match="family"):
        get_sampler("flat-post/walk")


def test_register_model_shadowing_reaches_composed_samplers():
    """Re-registering a model name propagates to factories composed (or
    cached) before the re-registration — the documented overwrite
    contract — because names re-resolve at bind time."""
    from repro.core.sampler import register_model
    register_model(CoinModel("shadow-m", lambda g: jnp.zeros(
        (g.m,), jnp.float32)))                      # p=0: roots only
    g = golden_graph()
    cfg = IMMConfig(batch=32)
    fn = get_sampler("shadow-m/dense")              # composed + cached now
    v, _, _ = fn(g, cfg)(jax.random.PRNGKey(0))
    assert int(np.asarray(v).sum(1).max()) == 1     # only roots visited
    register_model(CoinModel("shadow-m", lambda g: jnp.ones(
        (g.m,), jnp.float32)))                      # shadow: p=1
    v2, _, _ = fn(g, cfg)(jax.random.PRNGKey(0))
    assert int(np.asarray(v2).sum(1).max()) > 1     # reachability kicks in


def test_custom_coin_model_runs_every_backend():
    """Adding a diffusion model is one edge_probs function; every coin
    backend (incl. Pallas) executes it with no further code."""
    flat = CoinModel("flat-0.05", lambda g: jnp.full((g.m,), 0.05,
                                                     jnp.float32))
    g = golden_graph()
    cfg = IMMConfig(batch=64)
    key = jax.random.PRNGKey(2)
    sizes = {}
    for backend in ("dense", "sparse", "pallas"):
        fn = bind_sampler(make_sampler(flat, backend), g, cfg)
        v, c, r = fn(key)
        np.testing.assert_array_equal(np.asarray(c), np.asarray(v).sum(0))
        sizes[backend] = float(np.asarray(v).sum(1).mean())
    assert sizes["dense"] == sizes["pallas"]   # same math, same coins


# ------------------------------------------------- WC / GT end-to-end ----

@pytest.mark.parametrize("model", ["WC", "GT"])
def test_wc_gt_through_imm_and_engine(model):
    """The new models run the whole pipeline: imm() one-shot, then extra
    engine queries from the same store."""
    g = rmat_graph(192, 1536, seed=4)
    cfg = IMMConfig(k=4, model=model, batch=128, max_theta=512, seed=3)
    engine = InfluenceEngine(g, cfg)
    res = engine.run()
    assert len(set(int(s) for s in res.seeds)) == 4
    assert 0.0 < res.covered_frac <= 1.0
    assert res.influence == pytest.approx(res.covered_frac * g.n)
    sel = engine.select(2)
    np.testing.assert_array_equal(sel.seeds, res.seeds[:2])
    assert engine.influence(res.seeds) == pytest.approx(res.influence,
                                                        rel=1e-6)
    one_shot = imm(g, cfg)
    np.testing.assert_array_equal(one_shot.seeds, res.seeds)


@pytest.mark.parametrize("model", ["WC", "GT"])
def test_wc_gt_stream_refresh_equivalence(model):
    """The headline streaming invariant holds for the new models' stable
    forms: refresh-until-consistent == a fresh engine on the post-delta
    graph, seed-for-seed."""
    cfg = IMMConfig(k=4, batch=64, max_theta=512, seed=11, model=model)
    stream = StreamEngine(golden_graph(), cfg)
    assert stream.cfg.sampler == f"{model}/dense+stable"
    assert stream.engine.supports_row_resample
    stream.extend(256)
    rng = np.random.default_rng(21)
    for _ in range(2):
        stream.apply_delta(random_delta(
            stream.graph, rng, inserts=3, deletes=3, reweights=2))
    assert stream.refresh() == 0 and stream.consistent
    fresh = InfluenceEngine(stream.graph, stream.cfg)
    fresh.extend(stream.theta)
    a, b = stream.select(4), fresh.select(4)
    np.testing.assert_array_equal(a.seeds, b.seeds)
    np.testing.assert_array_equal(np.asarray(stream.store.counter),
                                  np.asarray(fresh.store.counter))


# -------------------------------------------------- the pallas backend ----

def test_pallas_backend_selectable_from_engine_config():
    """IMMConfig(backend='pallas') (the --sampler/--backend CLI path)
    drives kernels/ic_frontier.py through the engine and matches the
    dense backend's results exactly (off-TPU: ops.py oracle dispatch;
    pallas_interpret=True: the real kernel, interpreted)."""
    g = golden_graph()
    base = dict(k=3, batch=64, max_theta=256, seed=5)
    dense = InfluenceEngine(g, IMMConfig(backend="dense", **base))
    via_backend = InfluenceEngine(g, IMMConfig(backend="pallas", **base))
    via_name = InfluenceEngine(g, IMMConfig(sampler="IC/pallas", **base))
    interp = InfluenceEngine(g, IMMConfig(backend="pallas",
                                          pallas_interpret=True, **base))
    assert via_backend.sampler_name == via_name.sampler_name == "IC/pallas"
    results = {}
    for tag, e in (("dense", dense), ("backend", via_backend),
                   ("name", via_name), ("interp", interp)):
        e.extend(256)
        results[tag] = (np.asarray(e.store.counter), e.select(3).seeds)
    for tag in ("backend", "name", "interp"):
        np.testing.assert_array_equal(results[tag][0], results["dense"][0])
        np.testing.assert_array_equal(results[tag][1], results["dense"][1])


# ------------------------------------------- pow2 sparse edge padding ----

def test_stable_sparse_pads_edges_to_pow2_and_stays_bitwise():
    """The stable sparse backend pads its edge arrays to the next power
    of two (one jit trace per bucket, so a GraphDelta changing m inside
    the bucket never retraces) and padding is bitwise-invisible."""
    g = golden_graph()                       # m = 768 -> pads to 1024
    cfg = IMMConfig(batch=32)
    fn = bind_sampler(make_sampler("IC", "sparse", stable=True), g, cfg)
    key = jax.random.PRNGKey(9)
    v, c, r = fn(key)
    # the unpadded loop (direct call) produces the identical stream
    v0, c0, r0 = smp._sparse_loop(
        key, g.edge_src, g.edge_dst, g.in_prob, n_nodes=g.n, batch=32,
        stable=True)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(v0))
    np.testing.assert_array_equal(np.asarray(c), np.asarray(c0))
    np.testing.assert_array_equal(np.asarray(r), np.asarray(r0))


def test_stable_sparse_trace_width_shared_across_deltas():
    """Graphs whose edge counts fall in one pow2 bucket bind stable
    sparse samplers with identical static shapes — the compiled kernel
    is reused instead of retraced per delta."""
    g = golden_graph()
    stream = StreamEngine(g, IMMConfig(batch=32, seed=0,
                                       sampler="IC/sparse+stable"))
    widths = set()
    rng = np.random.default_rng(31)
    for _ in range(3):
        # the bound sampler closes over the padded arrays; peek by name
        bound = stream.engine._sample
        free = dict(zip(bound.__code__.co_freevars, bound.__closure__))
        widths.add(int(free["src"].cell_contents.shape[0]))
        stream.apply_delta(random_delta(stream.graph, rng, inserts=2,
                                        deletes=1))
    assert len(widths) == 1 and widths.pop() == 1024


# -------------------------------------------------- legacy deprecation ----

def test_legacy_names_warn_once_each():
    smp._LEGACY_WARNED.discard("IC-dense")
    with pytest.warns(DeprecationWarning, match="make_sampler"):
        get_sampler("IC-dense")
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        get_sampler("IC-dense")              # second resolve: silent
    # canonical names never warn
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        get_sampler("IC/dense")
        get_sampler("WC/pallas+stable")


def test_stable_variant_spellings():
    assert stable_variant("IC/dense") == "IC/dense+stable"
    assert stable_variant("LT/walk+stable") == "LT/walk+stable"
    assert stable_variant("IC-sparse") == "IC-sparse-stable"
    assert stable_variant("LT-stable") == "LT-stable"
    assert stable_variant("my-custom-sampler") == "my-custom-sampler"
