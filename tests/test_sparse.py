"""Property tests (hypothesis) for the shared sparse primitives — the layer
the IMM counters, GNN aggregation and recsys lookups all reduce to."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip on clean machines
from hypothesis import given, settings, strategies as st

from repro.sparse import (
    segment_sum, segment_max, segment_mean, segment_softmax,
    bincount_weighted, one_hot_matmul_count, embedding_bag,
)


settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


@st.composite
def segments(draw):
    n = draw(st.integers(1, 50))
    buckets = draw(st.integers(1, 10))
    ids = draw(st.lists(st.integers(0, buckets), min_size=n, max_size=n))
    data = draw(st.lists(
        st.floats(-10, 10, allow_nan=False, width=32),
        min_size=n, max_size=n))
    return (np.array(ids, np.int32), np.array(data, np.float32), buckets)


@given(segments())
def test_segment_sum_matches_numpy(sd):
    ids, data, buckets = sd
    got = segment_sum(jnp.asarray(data), jnp.asarray(ids), buckets)
    want = np.zeros(buckets, np.float32)
    for i, d in zip(ids, data):
        if i < buckets:      # sentinel ids drop
            want[i] += d
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


@given(segments())
def test_bincount_weighted_equals_one_hot_matmul(sd):
    ids, data, buckets = sd
    a = bincount_weighted(jnp.asarray(ids), jnp.asarray(data), buckets)
    b = one_hot_matmul_count(jnp.asarray(ids), jnp.asarray(data), buckets)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


@given(segments())
def test_segment_mean_bounded_by_extremes(sd):
    ids, data, buckets = sd
    mean = np.asarray(segment_mean(jnp.asarray(data), jnp.asarray(ids),
                                   buckets))
    for b in range(buckets):
        vals = data[ids == b]
        if len(vals):
            assert vals.min() - 1e-4 <= mean[b] <= vals.max() + 1e-4


@given(segments())
def test_segment_softmax_normalizes(sd):
    ids, data, buckets = sd
    sm = segment_softmax(jnp.asarray(data), jnp.asarray(ids), buckets)
    sums = np.asarray(segment_sum(sm, jnp.asarray(ids), buckets))
    for b in range(buckets):
        if (ids == b).any():
            assert sums[b] == jnp.asarray(1.0, jnp.float32) or \
                abs(sums[b] - 1.0) < 1e-4


def test_segment_max_with_neg_inf_padding():
    data = jnp.array([-jnp.inf, 3.0, -jnp.inf, 1.0])
    ids = jnp.array([0, 0, 1, 1])
    out = segment_max(data, ids, 3)
    assert float(out[0]) == 3.0 and float(out[1]) == 1.0


# ---------------------------------------------------------- embedding bag ----

@given(st.integers(1, 8), st.integers(1, 6), st.integers(2, 30),
       st.integers(1, 5))
def test_embedding_bag_fixed_len_matches_loop(bags, length, vocab, dim):
    key = jax.random.PRNGKey(bags * 7 + length)
    table = jax.random.normal(key, (vocab, dim))
    idx = jax.random.randint(jax.random.PRNGKey(1), (bags, length), 0, vocab)
    got = embedding_bag(table, idx, mode="sum")
    want = np.stack([np.asarray(table)[np.asarray(idx[b])].sum(0)
                     for b in range(bags)])
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_embedding_bag_offsets_and_padding():
    table = jnp.arange(12.0).reshape(6, 2)
    indices = jnp.array([0, 1, 2, 5, 6], jnp.int32)   # 6 == vocab -> pad
    offsets = jnp.array([0, 2, 4], jnp.int32)
    out = embedding_bag(table, indices, offsets, mode="sum")
    np.testing.assert_allclose(
        np.asarray(out),
        [[2.0, 4.0], [14.0, 16.0], [0.0, 0.0]])


def test_embedding_bag_modes():
    table = jnp.asarray(np.random.default_rng(0).normal(size=(10, 3)),
                        jnp.float32)
    idx = jnp.array([[1, 2, 3], [4, 4, 4]], jnp.int32)
    s = embedding_bag(table, idx, mode="sum")
    m = embedding_bag(table, idx, mode="mean")
    mx = embedding_bag(table, idx, mode="max")
    np.testing.assert_allclose(np.asarray(m), np.asarray(s) / 3, rtol=1e-5)
    np.testing.assert_allclose(
        np.asarray(mx)[1], np.asarray(table)[4], rtol=1e-5)


def test_sharded_embedding_lookup_single_device():
    """shard_map row-sharded lookup == plain take on a 1-device mesh."""
    from repro.compat import shard_map
    from repro.sparse import sharded_embedding_lookup
    from jax.sharding import PartitionSpec as P

    mesh = jax.make_mesh((1,), ("model",))
    table = jax.random.normal(jax.random.PRNGKey(0), (16, 4))
    ids = jnp.array([[0, 3], [15, 7]], jnp.int32)
    fn = shard_map(
        lambda t, i: sharded_embedding_lookup(
            t, i, axis_name="model", shard_rows=16),
        mesh=mesh, in_specs=(P("model", None), P()), out_specs=P())
    got = fn(table, ids)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.take(table, ids, axis=0)),
                               rtol=1e-6)
