"""IMTrace unit gates: registry exactness, span nesting, the disabled
no-op contract, thread-safety under concurrent recording, and the
single-device bitwise seed-identity guarantee (the forced-8-device 2x4
analogue lives in tests/force_obs_check.py)."""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.graphs import rmat_graph
from repro.obs.metrics import Histogram, MetricsRegistry, series_key


@pytest.fixture(autouse=True)
def _isolated_obs():
    """Each test starts disabled with empty registry/tracer and leaves
    the module switch the way it found it (off)."""
    obs.reset()
    yield
    obs.reset()


# ---------------------------------------------------------------- metrics


def test_histogram_percentiles_exact_on_bucket_boundaries():
    h = Histogram("t", buckets=(1.0, 2.0, 4.0, 8.0))
    # 100 observations, every one on a bucket boundary: quantiles are
    # exact, not bucket-rounded
    for v, times in ((1.0, 50), (2.0, 30), (4.0, 15), (8.0, 5)):
        for _ in range(times):
            h.observe(v)
    assert h.count == 100
    assert h.percentile(50.0) == 1.0     # rank 50 is the 50th 1.0
    assert h.percentile(51.0) == 2.0     # rank 51 crosses into 2.0
    assert h.percentile(80.0) == 2.0
    assert h.percentile(81.0) == 4.0
    assert h.percentile(95.0) == 4.0
    assert h.percentile(99.0) == 8.0
    assert h.percentile(100.0) == 8.0
    assert h.percentile(0.0) == 1.0      # rank clamps to the first obs
    assert h.sum == pytest.approx(50 + 60 + 60 + 40)


def test_histogram_overflow_reports_exact_max():
    h = Histogram("t", buckets=(1.0, 2.0))
    h.observe(0.5)
    h.observe(1000.0)                    # past the last bound: +Inf bucket
    h.observe(7.25)
    assert h.percentile(99.0) == 1000.0  # exact observed max, not "+Inf"
    d = h.to_dict()
    assert d["buckets"][-1] == ["+Inf", 2]
    assert d["max"] == 1000.0 and d["min"] == 0.5


def test_histogram_empty_and_validation():
    h = Histogram("t", buckets=(1.0,))
    assert h.percentile(50.0) == 0.0
    with pytest.raises(ValueError):
        h.percentile(101.0)
    with pytest.raises(ValueError):
        Histogram("t", buckets=())
    with pytest.raises(ValueError):
        Histogram("t", buckets=(2.0, 1.0))


def test_registry_identity_labels_and_kind_mismatch():
    reg = MetricsRegistry()
    a = reg.counter("serve.cache_hits", tenant="t0")
    b = reg.counter("serve.cache_hits", tenant="t0")
    c = reg.counter("serve.cache_hits", tenant="t1")
    assert a is b and a is not c
    assert a.key == series_key("serve.cache_hits", {"tenant": "t0"})
    assert a.key == "serve.cache_hits{tenant=t0}"
    with pytest.raises(TypeError):
        reg.gauge("serve.cache_hits", tenant="t0")
    reg.histogram("lat", buckets=(1.0, 2.0))
    with pytest.raises(ValueError):
        reg.histogram("lat", buckets=(1.0, 3.0))
    with pytest.raises(ValueError):
        reg.counter("neg").add(-1)


def test_gauge_tracks_running_max():
    reg = MetricsRegistry()
    g = reg.gauge("store.occupancy")
    assert g.max == 0.0                  # unwritten gauge reports zeros
    for v in (0.25, 0.9, 0.4):
        g.set(v)
    assert g.value == 0.4 and g.max == 0.9
    snap = reg.snapshot()
    assert snap["gauges"]["store.occupancy"] == {"value": 0.4, "max": 0.9}


def test_snapshot_schema_and_json_round_trip():
    obs.enable()
    obs.counter("c").add(3)
    obs.gauge("g").set(1.5)
    obs.histogram("h", buckets=(1.0, 2.0)).observe(1.0)
    snap = json.loads(json.dumps(obs.snapshot()))
    assert snap["counters"]["c"] == 3
    h = snap["histograms"]["h"]
    assert sum(c for _, c in h["buckets"]) == h["count"] == 1
    assert h["buckets"][-1][0] == "+Inf"


# ----------------------------------------------------------------- spans


def test_span_nesting_orders_depth_and_parent():
    obs.enable()
    with obs.span("run", tier="engine"):
        with obs.span("extend", tier="engine"):
            with obs.span("store.write", tier="store"):
                pass
        with obs.span("select", tier="engine"):
            pass
    evs = obs.get_tracer().events()
    # completion order: innermost first, root last
    assert [e["name"] for e in evs] == \
        ["store.write", "extend", "select", "run"]
    by_name = {e["name"]: e for e in evs}
    assert by_name["run"]["args"]["depth"] == 0
    assert by_name["run"]["args"]["parent"] == ""
    assert by_name["extend"]["args"] == \
        {**by_name["extend"]["args"], "depth": 1, "parent": "run"}
    assert by_name["store.write"]["args"]["depth"] == 2
    assert by_name["store.write"]["args"]["parent"] == "extend"
    assert by_name["select"]["args"]["parent"] == "run"
    # a child span lies inside its parent's [ts, ts+dur] window
    run, wr = by_name["run"], by_name["store.write"]
    assert run["ts"] <= wr["ts"]
    assert wr["ts"] + wr["dur"] <= run["ts"] + run["dur"] + 1e-6


def test_emit_helpers_consume_obs():
    """The BENCH emit helpers read the tracer/registry: span medians
    (with a last-N window) and snapshot scalars by series key."""
    import os
    import sys
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))
    from benchmarks._emit import git_sha, snapshot_scalar, span_median_s

    obs.enable()
    assert span_median_s("collective", "bench") == 0.0   # nothing yet
    for _ in range(5):
        with obs.span("collective", tier="bench"):
            pass
    assert span_median_s("collective", "bench") > 0.0
    durs = obs.get_tracer().durations_s("collective", "bench")
    import statistics
    assert span_median_s("collective", "bench", last=3) == \
        pytest.approx(statistics.median(durs[-3:]))
    obs.counter("c").add(7)
    obs.gauge("g").set(2.5)
    obs.histogram("h", buckets=(1.0, 2.0)).observe(2.0)
    snap = obs.snapshot()
    assert snapshot_scalar(snap, "c") == 7
    assert snapshot_scalar(snap, "g") == 2.5
    assert snapshot_scalar(snap, "h") == 2.0           # p50
    assert snapshot_scalar(snap, "absent", default=-1.0) == -1.0
    assert isinstance(git_sha(), str) and git_sha()    # never raises


def test_chrome_trace_is_valid_and_durations_readable():
    obs.enable()
    with obs.span("collective", tier="bench", step=1):
        pass
    trace = obs.chrome_trace()
    assert trace["displayTimeUnit"] == "ms"
    phs = [e["ph"] for e in trace["traceEvents"]]
    assert phs.count("M") == 1 and phs.count("X") == 1
    x = [e for e in trace["traceEvents"] if e["ph"] == "X"][0]
    assert x["cat"] == "bench" and x["args"]["step"] == 1
    assert x["dur"] >= 0
    durs = obs.get_tracer().durations_s("collective", "bench")
    assert len(durs) == 1 and durs[0] == pytest.approx(x["dur"] / 1e6)


def test_tracer_bounds_events_and_counts_drops():
    obs.enable(tracer=obs.Tracer(max_events=4))
    for i in range(10):
        with obs.span("s", i=i):
            pass
    tr = obs.get_tracer()
    assert len(tr) == 4 and tr.dropped == 6
    # the survivors are the newest events
    assert [e["args"]["i"] for e in tr.events()] == [6, 7, 8, 9]
    assert tr.chrome_trace()["otherData"]["dropped_events"] == 6


# ------------------------------------------------------------ switchboard


def test_disabled_mode_records_nothing():
    assert not obs.enabled()
    c = obs.counter("x")
    c.add(5)
    obs.gauge("y").set(1.0)
    obs.histogram("z").observe(3.0)
    with obs.span("run", tier="engine"):
        with obs.span("extend", tier="engine"):
            pass
    assert c is obs.gauge("anything")    # one shared no-op singleton
    assert c.value == 0 and c.percentile(99.0) == 0.0
    assert len(obs.get_metrics()) == 0
    assert len(obs.get_tracer()) == 0
    snap = obs.snapshot()
    assert snap == {"counters": {}, "gauges": {}, "histograms": {}}


def test_disable_keeps_data_reset_drops_it():
    obs.enable()
    obs.counter("c").add(1)
    with obs.span("s"):
        pass
    obs.disable()
    obs.counter("c").add(100)            # no-op while disabled
    assert obs.snapshot()["counters"]["c"] == 1
    assert len(obs.get_tracer()) == 1
    obs.enable()
    obs.counter("c").add(1)              # same series continues
    assert obs.snapshot()["counters"]["c"] == 2
    obs.reset()
    assert not obs.enabled()
    assert obs.snapshot() == {"counters": {}, "gauges": {}, "histograms": {}}


def test_concurrent_recording_is_exact():
    """Many worker threads (the IMServe pattern) hammer one counter, one
    gauge, and one labeled histogram — no lost increments, no torn
    bucket counts."""
    obs.enable()
    threads, per = 8, 500

    def work(t):
        c = obs.counter("serve.cache_hits", tenant="t0")
        h = obs.histogram("serve.latency_ms", tenant="t0",
                          buckets=(1.0, 2.0, 4.0))
        for i in range(per):
            c.add(1)
            h.observe(float(1 << (i % 3)))
            obs.gauge("serve.queue_depth", tenant="t0").set(i)
            with obs.span("cache", tier="serve", worker=t):
                pass

    ts = [threading.Thread(target=work, args=(t,)) for t in range(threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    snap = obs.snapshot()
    assert snap["counters"]["serve.cache_hits{tenant=t0}"] == threads * per
    h = snap["histograms"]["serve.latency_ms{tenant=t0}"]
    assert h["count"] == threads * per
    assert sum(c for _, c in h["buckets"]) == h["count"]
    # each boundary value got exactly its share
    assert [c for _, c in h["buckets"]][:3] == \
        [threads * len(range(i, per, 3)) for i in range(3)]
    assert len(obs.get_tracer().events("cache", "serve")) == threads * per


# ----------------------------------------------------- numerics invariance


def test_obs_on_off_bitwise_identical_single_device():
    """The acceptance property, single-device: a fully instrumented run
    (spans + metrics live) is seed-for-seed bitwise identical to the
    disabled run, and the enabled run actually traced the engine and
    store tiers with nesting."""
    g = rmat_graph(96, 512, seed=2)
    cfg = IMMConfig(k=4, batch=64, max_theta=128, seed=3)

    r_off = InfluenceEngine(g, cfg).run()
    assert not obs.enabled()

    obs.enable()
    eng = InfluenceEngine(g, cfg)
    r_on = eng.run()
    inf_on = eng.influences([r_on.seeds[:2]])
    obs.disable()

    np.testing.assert_array_equal(np.asarray(r_off.seeds),
                                  np.asarray(r_on.seeds))
    np.testing.assert_array_equal(np.asarray(r_off.counter),
                                  np.asarray(r_on.counter))
    assert r_off.theta == r_on.theta
    assert r_off.influence == r_on.influence
    eng_off = InfluenceEngine(g, cfg)
    eng_off.extend(r_off.theta)
    np.testing.assert_allclose(inf_on,
                               eng_off.influences([r_on.seeds[:2]]),
                               rtol=1e-6)

    # the enabled run produced real telemetry: nested engine + store spans
    tr = obs.get_tracer()
    assert tr.events(tier="engine") and tr.events(tier="store")
    ext = tr.events("extend", "engine")
    assert ext and all(e["args"]["parent"] in ("run", "round")
                       for e in ext)
    wr = tr.events("store.write", "store")
    assert wr and all(e["args"]["depth"] >= 2 for e in wr)
    snap = obs.snapshot()
    assert snap["counters"]["engine.rounds"] >= 1
    assert snap["counters"]["store.rows_written"] == r_on.theta
    assert snap["gauges"]["engine.theta"]["value"] == r_on.theta
    assert 0.0 < snap["gauges"]["store.occupancy"]["value"] <= 1.0
