"""IMServe serving-tier tests: admission + DRR fairness, the epoch-keyed
result cache (entries never survive an epoch advance; a hit is bitwise
identical to recomputing), replica snapshot fan-out, SLO-aware refresh
scheduling, epoch consistency under racing refresh threads, and the
hardened IMServer/IMServe lifecycle (idempotent start, multi-stop,
bounded drain)."""
import threading
import time

import numpy as np
import pytest

from repro.checkpoint import store as ckpt
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.graphs import rmat_graph
from repro.launch.serve import IMServer
from repro.serve import (
    AdmissionError, DeficitRoundRobin, IMServe, QueryTicket, ReplicaGroup,
    ResultCache, RefreshScheduler, TenantSpec, make_trace, replay,
    trace_summary, zipf_rates,
)
from repro.stream import StreamEngine, random_delta


def small_graph(seed=2):
    return rmat_graph(96, 768, seed=seed)


def small_cfg(seed=0, theta=256):
    return IMMConfig(k=4, batch=64, max_theta=max(theta, 512), seed=seed)


def _tier(**kw):
    kw.setdefault("quantum", 4)
    return IMServe(**kw)


def _spec(name, seed=2, **kw):
    kw.setdefault("graph", small_graph(seed))
    kw.setdefault("cfg", small_cfg(seed))
    kw.setdefault("theta", 256)
    return TenantSpec(name, **kw)


# ------------------------------------------------- admission + fairness ----

def test_drr_weighted_rounds_and_no_hoarding():
    q = DeficitRoundRobin(quantum=4)
    q.register("heavy", weight=2.0, max_pending=100)
    q.register("light", weight=1.0, max_pending=100)
    tid = iter(range(1000))
    for _ in range(20):
        q.submit(QueryTicket(next(tid), "heavy", np.array([1])))
    for _ in range(6):
        q.submit(QueryTicket(next(tid), "light", np.array([2])))
    r1 = dict(q.take_round())
    # one round = quantum * weight queries for a backlogged tenant
    assert len(r1["heavy"]) == 8 and len(r1["light"]) == 4
    r2 = dict(q.take_round())
    assert len(r2["heavy"]) == 8
    # light emptied this round; its leftover credit must not hoard
    assert len(r2["light"]) == 2
    q.submit(QueryTicket(next(tid), "light", np.array([2])))
    r3 = dict(q.take_round())
    assert len(r3["light"]) == 1      # fresh credit only, no carry-over
    assert len(r3["heavy"]) == 4      # heavy drains its last 4 this round
    assert q.pending() == 0


def test_admission_rejects_at_cap_not_unbounded():
    q = DeficitRoundRobin(quantum=4)
    q.register("t", weight=1.0, max_pending=3)
    admitted = [q.try_submit(QueryTicket(i, "t", np.array([i])))
                for i in range(10)]
    assert admitted == [True] * 3 + [False] * 7
    assert q.pending("t") == 3
    with pytest.raises(AdmissionError, match="queue full"):
        q.submit(QueryTicket(99, "t", np.array([0])))
    q.take_round()
    assert q.try_submit(QueryTicket(100, "t", np.array([0])))


def test_fairness_starvation_free_under_flood():
    """A light tenant behind a flooding heavy tenant is fully served
    within its DRR bound (ceil(pending / (quantum * weight)) rounds) —
    the starvation-freedom guarantee."""
    q = DeficitRoundRobin(quantum=4)
    q.register("flood", weight=1.0, max_pending=10_000)
    q.register("light", weight=1.0, max_pending=10_000)
    tid = iter(range(10_000))
    for _ in range(400):
        q.submit(QueryTicket(next(tid), "flood", np.array([1])))
    for _ in range(10):
        q.submit(QueryTicket(next(tid), "light", np.array([2])))
    served_light = 0
    rounds = 0
    while q.pending("light"):
        rounds += 1
        for name, batch in q.take_round():
            if name == "light":
                served_light += len(batch)
    assert served_light == 10
    assert rounds <= -(-10 // 4)              # ceil(10/quantum) == 3
    assert q.pending("flood") > 0             # flood still backlogged


# ------------------------------------------------------------ result cache --

def test_cache_key_erases_seed_order_and_duplicates():
    k1 = ResultCache.key("t", 3, [3, 1, 3])
    k2 = ResultCache.key("t", 3, np.array([1, 3], np.int32))
    assert k1 == k2
    assert ResultCache.key("t", 4, [1, 3]) != k1
    assert ResultCache.key("u", 3, [1, 3]) != k1


def test_cache_advance_drops_exactly_the_old_epochs():
    c = ResultCache(max_entries=64)
    for e in (0, 1):
        for s in range(4):
            c.put(ResultCache.key("a", e, [s]), float(10 * e + s))
    c.put(ResultCache.key("b", 0, [7]), 7.0)
    dropped = c.advance("a", 1)
    assert dropped == 4 and c.invalidations == 4
    assert c.epochs("a") == {1}               # at most a singleton
    assert c.entries("a") == 4 and c.entries("b") == 1
    assert c.get(ResultCache.key("a", 0, [2])) is None
    assert c.get(ResultCache.key("a", 1, [2])) == 12.0
    assert c.get(ResultCache.key("b", 0, [7])) == 7.0  # other tenant kept
    assert c.advance("a", 1) == 0             # idempotent


def test_cache_lru_bound_and_hit_rate():
    c = ResultCache(max_entries=3)
    for s in range(5):
        c.put(ResultCache.key("t", 0, [s]), float(s))
    assert len(c) == 3 and c.evictions == 2
    assert c.get(ResultCache.key("t", 0, [0])) is None   # evicted first
    assert c.get(ResultCache.key("t", 0, [4])) == 4.0
    assert 0 < c.hit_rate < 1
    # a hit refreshes recency: [2] touched, then two inserts evict 3, 4
    c.get(ResultCache.key("t", 0, [2]))
    c.put(ResultCache.key("t", 0, [5]), 5.0)
    c.put(ResultCache.key("t", 0, [6]), 6.0)
    assert c.get(ResultCache.key("t", 0, [2])) == 2.0


# ------------------------------------------------------- refresh scheduler --

def test_scheduler_allocates_proportional_to_weighted_backlog():
    s = RefreshScheduler(budget=100)
    out = s.allocate({"a": 300, "b": 100, "idle": 0})
    grants = {a.tenant: a.budget for a in out}
    assert "idle" not in grants
    assert sum(grants.values()) == 100
    assert grants["a"] == 75 and grants["b"] == 25
    # weights multiply backlog into priority
    out = s.allocate({"a": 100, "b": 100}, {"a": 3.0, "b": 1.0})
    grants = {a.tenant: a.budget for a in out}
    assert grants["a"] == 75 and grants["b"] == 25
    assert s.steps == 2 and s.rows_granted == 200


def test_scheduler_floor_caps_and_small_budget():
    s = RefreshScheduler(budget=10)
    # grants never exceed a tenant's backlog; surplus flows to others
    grants = {a.tenant: a.budget for a in s.allocate({"a": 3, "b": 100})}
    assert grants["a"] <= 3 and sum(grants.values()) == 10
    # every covered tenant gets >= 1 even with a tiny share
    grants = {a.tenant: a.budget for a in s.allocate({"a": 1, "b": 1000})}
    assert grants["a"] >= 1 and sum(grants.values()) == 10
    # budget larger than total backlog: grant exactly the backlog
    grants = {a.tenant: a.budget for a in s.allocate({"a": 2, "b": 3})}
    assert sum(grants.values()) == 5
    assert s.allocate({"a": 0}) == []
    with pytest.raises(ValueError, match=">= 1"):
        RefreshScheduler(0)


# ------------------------------------------------------ stream accounting --

def test_stream_engine_repair_accounting():
    stream = StreamEngine(small_graph(), small_cfg())
    stream.extend(256)
    assert stream.refreshes == 0 and stream.rows_repaired == 0
    assert stream.backlog == 0
    stream.apply_delta(random_delta(stream.graph,
                                    np.random.default_rng(5), deletes=4))
    backlog = stream.backlog
    assert backlog == stream.stale > 0
    stream.refresh()
    assert stream.backlog == 0
    assert stream.refreshes == 1
    assert stream.rows_repaired == stream.last_repair == backlog


# -------------------------------------------------- snapshot fan-out bits --

def test_clone_tree_deep_copies_and_tree_bytes():
    eng = InfluenceEngine(small_graph(), small_cfg())
    eng.extend(256)
    tree = eng.snapshot_tree()
    clone = ckpt.clone_tree(tree)
    assert ckpt.tree_bytes(clone) == ckpt.tree_bytes(tree) > 0
    _, leaves = ckpt._flatten(clone)
    _, orig = ckpt._flatten(tree)
    k = next(iter(leaves))
    before = np.array(orig[k])
    np.asarray(leaves[k])[...] = 0            # mutate the clone...
    np.testing.assert_array_equal(np.asarray(orig[k]), before)  # ...only


def test_engine_replicate_is_bitwise_and_independent():
    eng = InfluenceEngine(small_graph(), small_cfg())
    eng.extend(256)
    rep = eng.replicate()
    assert rep is not eng
    np.testing.assert_array_equal(np.asarray(rep.store.counter),
                                  np.asarray(eng.store.counter))
    sets = [np.array([1, 5], np.int32), np.array([7], np.int32)]
    np.testing.assert_array_equal(np.asarray(rep.influences(sets)),
                                  np.asarray(eng.influences(sets)))


def test_replica_group_serves_only_after_sync_and_tracks_epochs():
    stream = StreamEngine(small_graph(), small_cfg())
    stream.extend(256)
    group = ReplicaGroup(stream, 2)
    assert not group.servable
    with pytest.raises(RuntimeError, match="sync"):
        group.influences([np.array([1], np.int32)])
    group.sync(stream.epoch)
    assert group.servable and group.synced_epoch == 0
    probe = [np.array([3, 9], np.int32)]
    want = np.asarray(stream.influences(probe))
    for _ in range(2):                        # both round-robin replicas
        np.testing.assert_array_equal(np.asarray(group.influences(probe)),
                                      want)
    # primary advances; the group lags at its synced epoch until resync
    stream.apply_delta(random_delta(stream.graph,
                                    np.random.default_rng(6), deletes=3))
    stream.refresh()
    assert group.synced_epoch == 0 and stream.epoch == 1
    group.sync(stream.epoch)
    assert group.synced_epoch == 1 and group.syncs == 2
    assert group.bytes_shipped > 0
    np.testing.assert_array_equal(np.asarray(group.influences(probe)),
                                  np.asarray(stream.influences(probe)))


# ------------------------------------------------------------- tier: cache --

def test_tier_cached_sigma_is_bitwise_identical():
    tier = _tier()
    tier.register(_spec("a"))
    seeds = np.array([3, 11, 40], np.int32)
    t1 = tier.submit("a", seeds)
    tier.flush()
    t2 = tier.submit("a", seeds[::-1])        # same set, different order
    tier.flush()
    r1, r2 = tier.result(t1), tier.result(t2)
    assert not r1.cached and r2.cached
    assert r2.value == r1.value               # bitwise, not approx
    with tier.tenants["a"].lock:
        direct = float(np.asarray(
            tier.tenants["a"].engine.influences([seeds]))[0])
    assert r1.value == direct


def test_tier_cache_entries_never_survive_epoch_advance():
    tier = _tier(refresh_budget=512)
    tier.register(_spec("s", streaming=True))
    rng = np.random.default_rng(7)
    probe = np.array([2, 17], np.int32)
    for _ in range(3):
        tier.submit("s", probe)
        tier.submit("s", rng.choice(96, size=4, replace=False))
        tier.flush()
        assert tier.cache.epochs("s") == {tier.tenants["s"].served_epoch}
        tier.apply_delta("s", random_delta(tier.tenants["s"].graph, rng,
                                           inserts=2, deletes=2))
        assert tier.drain(timeout=60.0)
    # entries still keyed at the pre-delta epoch die on the next serve
    t = tier.submit("s", probe)
    tier.flush()
    assert tier.cache.epochs("s") == {3}
    assert tier.result(t).epoch == 3 and not tier.result(t).cached
    assert tier.cache.invalidations > 0


def test_tier_mid_repair_answers_bypass_cache():
    """While a tenant's backlog is unrepaired, the store keeps changing
    within the epoch — those answers are neither written to nor read
    from the cache; caching resumes at the next consistent state."""
    tier = _tier(refresh_budget=512)
    tier.register(_spec("s", streaming=True))
    probe = np.array([4, 21, 50], np.int32)
    tier.submit("s", probe)
    tier.flush()
    assert tier.cache.entries("s") == 1       # consistent: cached
    tier.apply_delta("s", random_delta(tier.tenants["s"].graph,
                                       np.random.default_rng(17),
                                       deletes=4, inserts=4))
    assert tier.tenants["s"].backlog > 0
    t1 = tier.submit("s", probe)
    tier.flush()
    t2 = tier.submit("s", probe)
    tier.flush()
    # epoch advanced (old entries dropped) but mid-repair wrote nothing
    assert tier.cache.entries("s") == 0
    assert not tier.result(t1).cached and not tier.result(t2).cached
    assert tier.drain(timeout=60.0)
    t3 = tier.submit("s", probe)
    tier.flush()
    t4 = tier.submit("s", probe)
    tier.flush()
    assert not tier.result(t3).cached and tier.result(t4).cached
    assert tier.result(t4).value == tier.result(t3).value


def test_tier_shared_engine_slot():
    tier = _tier()
    tier.register(_spec("host"))
    tier.register(TenantSpec("guest", share_engine_with="host"))
    guest = tier.tenants["guest"]
    assert not guest.owns_engine
    assert guest.engine is tier.tenants["host"].engine
    assert guest.lock is tier.tenants["host"].lock
    seeds = np.array([5, 23], np.int32)
    t1 = tier.submit("host", seeds)
    t2 = tier.submit("guest", seeds)
    tier.flush()
    # same engine -> same sigma; per-tenant cache keys -> both missed
    assert tier.result(t1).value == tier.result(t2).value
    assert not tier.result(t1).cached and not tier.result(t2).cached
    assert guest.stats()["shared_engine"]
    with pytest.raises(ValueError, match="unknown tenant"):
        tier.register(TenantSpec("x", share_engine_with="nobody"))


def test_tier_admission_and_error_paths():
    tier = _tier()
    tier.register(_spec("a", max_pending=2))
    assert tier.try_submit("a", [1]) is not None
    assert tier.try_submit("a", [2]) is not None
    assert tier.try_submit("a", [3]) is None
    with pytest.raises(AdmissionError, match="queue full"):
        tier.submit("a", [4])
    assert tier.tenants["a"].rejected == 2
    tier.flush()
    with pytest.raises(ValueError, match="streaming"):
        tier.apply_delta("a", None)           # static tenant
    with pytest.raises(KeyError, match="unknown tenant"):
        tier.submit("ghost", [1])
    with pytest.raises(ValueError, match="already registered"):
        tier.register(_spec("a"))
    with pytest.raises(ValueError, match="slo"):
        TenantSpec("bad", graph=small_graph(), slo="gold")
    with pytest.raises(ValueError, match="needs a graph"):
        TenantSpec("bad2")


# ---------------------------------------------------------- tier: replicas --

def test_tier_relaxed_slo_routes_to_replicas():
    tier = _tier()
    tier.register(_spec("strict"))
    tier.register(_spec("relax", seed=3, slo="relaxed", replicas=2))
    t1 = tier.submit("strict", [4, 9])
    t2 = tier.submit("relax", [4, 9])
    tier.flush()
    assert not tier.result(t1).replica
    assert tier.result(t2).replica
    assert tier.tenants["relax"].replica_reads == 1
    # replica answer == primary answer at the same (static) epoch
    with tier.tenants["relax"].lock:
        want = float(np.asarray(tier.tenants["relax"].engine.influences(
            [np.array([4, 9], np.int32)]))[0])
    assert tier.result(t2).value == want


def test_tier_replicas_resync_only_at_consistent_epochs():
    tier = _tier(refresh_budget=512)
    tier.register(_spec("r", streaming=True, slo="relaxed", replicas=1))
    group = tier.replica_groups["r"]
    assert group.synced_epoch == 0
    rng = np.random.default_rng(9)
    tier.apply_delta("r", random_delta(tier.tenants["r"].graph, rng,
                                       deletes=3, inserts=3))
    # primary is mid-repair (stale > 0): sync_replicas must hold back
    if tier.tenants["r"].backlog > 0:
        assert tier.sync_replicas() == 0
        assert group.synced_epoch == 0
    assert tier.drain(timeout=60.0)
    assert group.synced_epoch == tier.tenants["r"].epoch == 1
    t = tier.submit("r", [1, 2])
    tier.flush()
    assert tier.result(t).replica and tier.result(t).epoch == 1


# ----------------------------------------------- tier: refresh scheduling --

def test_tier_refresh_step_spends_budget_where_deltas_landed():
    tier = _tier(refresh_budget=16)
    tier.register(_spec("hot", streaming=True))
    tier.register(_spec("cold", seed=4, streaming=True))
    tier.register(_spec("static", seed=5))
    rng = np.random.default_rng(11)
    tier.apply_delta("hot", random_delta(tier.tenants["hot"].graph, rng,
                                         deletes=4, inserts=4))
    allocs = tier.refresh_step()
    assert {a.tenant for a in allocs} == {"hot"}   # cold/static: no budget
    assert sum(a.budget for a in allocs) <= 16
    assert tier.drain(timeout=60.0)
    assert tier.backlog == 0
    # drained engine == fresh engine on the post-delta graph
    hot = tier.tenants["hot"]
    fresh = InfluenceEngine(hot.graph, hot.engine.cfg)
    fresh.extend(hot.engine.theta)
    np.testing.assert_array_equal(
        np.asarray(hot.engine.store.counter),
        np.asarray(fresh.store.counter))


def test_tier_refresh_requires_budget():
    tier = _tier()
    with pytest.raises(ValueError, match="refresh_budget"):
        tier.refresh_step()
    with pytest.raises(ValueError, match="refresh_budget"):
        tier.start_refresh_worker()


# ------------------------------------------------- epoch consistency race --

def test_tier_queries_stay_epoch_consistent_under_racing_refresh():
    """Queries racing the background refresh worker and a delta stream:
    each DRR batch is answered under the tenant lock against exactly one
    store state, so identical seed sets in one batch get identical
    values and one epoch tag — no torn reads against concurrent repair
    slices.  Cached answers only ever come from consistent states, so
    after the drain a cache hit equals a fresh engine bitwise."""
    tier = _tier(refresh_budget=32)
    tier.register(_spec("s", streaming=True))
    probe = np.array([8, 33, 60], np.int32)
    batches = []
    stop = threading.Event()
    errors = []

    def mutate():
        rng = np.random.default_rng(13)
        try:
            while not stop.is_set():
                tier.apply_delta("s", random_delta(
                    tier.tenants["s"].graph, rng, inserts=2, deletes=2))
                time.sleep(0.002)
        except Exception as e:                # pragma: no cover
            errors.append(e)

    with tier:
        tier.start_refresh_worker()
        mut = threading.Thread(target=mutate)
        mut.start()
        try:
            for _ in range(10):
                # three identical submits served in ONE DRR batch (one
                # lock hold, one store state, one epoch)
                batch = [tier.submit("s", probe) for _ in range(3)]
                tier.flush()
                batches.append(batch)
        finally:
            stop.set()
            mut.join()
        assert tier.drain(timeout=60.0)
    assert not errors
    for batch in batches:
        recs = [tier.result(t) for t in batch]
        assert all(r is not None and r.tenant == "s" for r in recs)
        assert len({r.value for r in recs}) == 1, "torn read in one batch"
        assert len({r.epoch for r in recs}) == 1
    # post-drain: the consistent-state answer equals a fresh engine's —
    # and a repeat is a cache hit with the bitwise-identical value
    s = tier.tenants["s"]
    fresh = InfluenceEngine(s.graph, s.engine.cfg)
    fresh.extend(s.engine.theta)
    t1 = tier.submit("s", probe)
    tier.flush()
    t2 = tier.submit("s", probe)
    tier.flush()
    assert tier.result(t1).value == pytest.approx(
        float(np.asarray(fresh.influences([probe]))[0]), rel=1e-6)
    assert tier.result(t2).cached
    assert tier.result(t2).value == tier.result(t1).value


# -------------------------------------------------------- trace generator --

def test_trace_is_deterministic_and_skewed():
    graphs = {"a": small_graph(2), "b": small_graph(3)}
    kw = dict(duration=0.5, qps=80.0, streaming={"b": True},
              delta_period=0.2, seed=4)
    t1, t2 = make_trace(graphs, **kw), make_trace(graphs, **kw)
    assert len(t1) == len(t2) > 0
    for e1, e2 in zip(t1, t2):
        assert (e1.t, e1.tenant, e1.kind) == (e2.t, e2.tenant, e2.kind)
        if e1.seeds is not None:
            np.testing.assert_array_equal(e1.seeds, e2.seeds)
    assert [e.t for e in t1] == sorted(e.t for e in t1)
    s = trace_summary(t1)
    assert s["b"]["deltas"] == 2 and s["a"]["deltas"] == 0
    assert s["a"]["queries"] > 0
    rates = zipf_rates(["a", "b", "c"], 90.0, 1.0,
                       np.random.default_rng(0))
    assert sum(rates.values()) == pytest.approx(90.0)
    assert max(rates.values()) > min(rates.values())


def test_replay_answers_admitted_queries_and_counts_rejections():
    tier = _tier()
    tier.register(_spec("a", max_pending=2))
    events = make_trace({"a": tier.tenants["a"].graph}, duration=0.5,
                        qps=40.0, seed=5)
    answered, rejected = replay(tier, events, pump_every=2)
    n_queries = trace_summary(events)["a"]["queries"]
    assert len(answered) + rejected == n_queries
    assert len(answered) > 0
    for tid, val in answered.items():
        assert tier.result(tid).value == val


# ------------------------------------------------------ lifecycle: IMServe --

def test_imserve_lifecycle_idempotent_and_restartable():
    tier = _tier(refresh_budget=64)
    tier.register(_spec("s", streaming=True))
    tier.start_refresh_worker()
    tier.start_refresh_worker()               # idempotent
    assert tier.refreshing
    tier.stop_refresh_worker()
    tier.stop_refresh_worker()                # safe twice
    assert not tier.refreshing
    tier.start_refresh_worker()               # restartable after stop
    assert tier.refreshing
    tier.close()
    with tier:
        tier.start_refresh_worker()
    assert not tier.refreshing                # __exit__ stopped it
    tier.close()                              # and close after exit is fine
    stats = tier.stats()
    assert stats["refresh"]["budget"] == 64


def test_imserve_drain_inline_without_worker_and_timeout():
    tier = _tier(refresh_budget=8)
    tier.register(_spec("s", streaming=True))
    rng = np.random.default_rng(15)
    tier.apply_delta("s", random_delta(tier.tenants["s"].graph, rng,
                                       deletes=4, inserts=4))
    assert tier.backlog > 0
    before = tier.backlog
    assert not tier.drain(timeout=0.0)        # deadline honored inline...
    assert tier.backlog < before              # ...with partial progress
    assert tier.drain(timeout=None)           # None waits it out
    assert tier.backlog == 0


# ----------------------------------------------------- lifecycle: IMServer --

def test_imserver_start_idempotent_and_restartable():
    stream = StreamEngine(small_graph(), small_cfg())
    stream.extend(256)
    server = IMServer(stream, refresh_budget=64)
    server.start_refresh_worker()
    first = server._worker
    server.start_refresh_worker()             # idempotent: same worker
    assert server._worker is first and server.async_refreshing
    server.stop_refresh_worker()
    server.stop_refresh_worker()              # safe twice
    assert not server.async_refreshing
    server.start_refresh_worker()             # restartable
    assert server.async_refreshing
    server.close()
    with server:
        server.start_refresh_worker()
    assert not server.async_refreshing        # __exit__ stopped it
    server.close()                            # close after __exit__
    engine = InfluenceEngine(small_graph(), small_cfg())
    with pytest.raises(ValueError, match="refresh_budget"):
        IMServer(engine).start_refresh_worker()


def test_imserver_drain_timeout_inline_and_forever():
    stream = StreamEngine(small_graph(), small_cfg())
    stream.extend(256)
    server = IMServer(stream, refresh_budget=4)
    server.apply_delta(random_delta(stream.graph,
                                    np.random.default_rng(16),
                                    deletes=4, inserts=4))
    assert stream.stale > 0
    before = stream.stale
    assert not server.drain(timeout=0.0)      # finite timeout honored
    assert stream.stale < before              # partial progress kept
    assert server.drain(timeout=None)
    assert stream.stale == 0
    assert server.drain(timeout=0.0)          # already drained: True
