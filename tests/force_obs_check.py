"""Subprocess body for the forced multi-device IMTrace acceptance cell.

Run by scripts/ci.sh with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` and ``--mesh
2x4`` so the obs instrumentation executes against real (host-platform)
multi-device buffers.  Asserts the observability acceptance criteria
(docs/observability.md):

  * a fully-instrumented meshed engine run (spans + metrics live, all
    tiers recording) is **seed-for-seed bitwise identical** to the same
    run with obs disabled — observability provably changes no numerics;
  * the exported Chrome trace contains **nested** spans from the
    engine, store, stream, and serve tiers;
  * a meshed `IMServe` campaign (strict + relaxed/replicated +
    streaming tenants, repeated queries, a delta + refresh) reports
    non-zero per-tenant p50/p99 latency histograms, cache hit/miss
    counters, and queue-depth gauges in its metrics snapshot, plus an
    SLO-violation count for a tenant with an (intentionally
    unmeetable) ``latency_slo_ms``;
  * both export artifacts round-trip through `scripts.check_obs`'s
    validators.

Prints one JSON line on success (consumed by scripts/ci.sh).
"""
import argparse
import json
import os
import sys
import tempfile

import numpy as np
import jax

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir))
from scripts.check_obs import check_metrics, check_trace  # noqa: E402

from repro import obs                                     # noqa: E402
from repro.configs.imm_snap import (                      # noqa: E402
    make_im_mesh, mesh_engine_kwargs,
)
from repro.core.engine import InfluenceEngine, IMMConfig  # noqa: E402
from repro.graphs import rmat_graph                       # noqa: E402
from repro.serve.tier import IMServe                      # noqa: E402
from repro.serve.tenant import TenantSpec                 # noqa: E402
from repro.stream.delta import random_delta               # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="2x4",
                    help="layout to check: an int (1D) or 'RxC' (2D)")
    args = ap.parse_args(argv)

    mesh = make_im_mesh(args.mesh)
    n_dev = jax.device_count()
    want = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))
    assert n_dev == want, \
        f"mesh {args.mesh} wants {want} forced host devices, got {n_dev}"
    kw = mesh_engine_kwargs(mesh)

    g = rmat_graph(128, 1024, seed=4)
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3)

    # --- obs OFF: the reference run ------------------------------------
    assert not obs.enabled()
    off = InfluenceEngine(g, cfg, **kw)
    r_off = off.run()
    inf_off = np.asarray(off.influences([r_off.seeds[:3], r_off.seeds]))

    # --- obs ON: same config, same mesh, everything recording ----------
    obs.reset()
    obs.enable()
    on = InfluenceEngine(g, cfg, **kw)
    r_on = on.run()
    inf_on = np.asarray(on.influences([r_on.seeds[:3], r_on.seeds]))

    # bitwise seed identity: obs provably changed no numerics
    np.testing.assert_array_equal(np.asarray(r_off.seeds),
                                  np.asarray(r_on.seeds))
    np.testing.assert_array_equal(np.asarray(r_off.counter),
                                  np.asarray(r_on.counter))
    assert r_off.theta == r_on.theta
    assert r_off.influence == r_on.influence
    np.testing.assert_array_equal(inf_off, inf_on)

    # --- a meshed IMServe campaign on the same mesh --------------------
    tier = IMServe(quantum=8, refresh_budget=256, mesh_kwargs=kw)
    tier.register(TenantSpec("brand-a", graph=g, cfg=cfg, theta=128,
                             latency_slo_ms=250.0))
    tier.register(TenantSpec("brand-b", graph=g, cfg=cfg, theta=128,
                             slo="relaxed", replicas=1,
                             latency_slo_ms=1e-3))   # unmeetably tight
    tier.register(TenantSpec("evolving", graph=g, cfg=cfg, theta=128,
                             streaming=True))
    rng = np.random.default_rng(11)
    queries = [rng.choice(g.n, size=3, replace=False) for _ in range(6)]
    for name in ("brand-a", "brand-b", "evolving"):
        for S in queries:
            tier.submit(name, S)
    tier.flush()
    # the same queries again: epoch unchanged -> these must hit the cache
    for name in ("brand-a", "brand-b"):
        for S in queries:
            tier.submit(name, S)
    tier.flush()
    # a delta + SLO-aware refresh on the streaming tenant (stream spans)
    stale = tier.apply_delta(
        "evolving", random_delta(g, np.random.default_rng(5), reweights=8))
    assert stale >= 0
    while tier.backlog:
        assert tier.refresh_step()
    assert tier.sync_replicas() >= 0

    snap = tier.metrics()

    # per-tenant latency histograms: non-zero counts and quantiles
    for name in ("brand-a", "brand-b", "evolving"):
        h = snap["histograms"][f"serve.latency_ms{{tenant={name}}}"]
        assert h["count"] >= len(queries), (name, h["count"])
        assert h["p50"] > 0.0 and h["p99"] >= h["p50"], (name, h)
        assert sum(c for _, c in h["buckets"]) == h["count"]
    # cache behaviour: the replayed queries hit, the first pass missed
    for name in ("brand-a", "brand-b"):
        hits = snap["counters"][f"serve.cache_hits{{tenant={name}}}"]
        misses = snap["counters"][f"serve.cache_misses{{tenant={name}}}"]
        assert hits >= len(queries), (name, hits)
        assert misses >= len(queries), (name, misses)
    # queue depth was observed non-zero while the submits were backlogged
    for name in ("brand-a", "brand-b", "evolving"):
        qd = snap["gauges"][f"serve.queue_depth{{tenant={name}}}"]
        assert qd["max"] >= 1.0, (name, qd)
    assert snap["counters"]["serve.drr_rounds"] >= 2
    # the unmeetable SLO tenant accumulated violations; the lax one none
    assert snap["counters"]["serve.slo_violations{tenant=brand-b}"] > 0
    assert "serve.slo_violations{tenant=brand-a}" not in snap["counters"]
    # the replica group's snapshot fan-out was timed
    assert snap["histograms"]["serve.replica_sync_ms"]["count"] >= 1
    # the engine/store instrumentation recorded through the tier too
    assert snap["counters"]["store.rows_written"] >= r_on.theta
    assert snap["counters"]["stream.refreshes"] >= 1

    # --- nested spans from every instrumented tier ---------------------
    tr = obs.get_tracer()
    for tier_name in ("engine", "store", "stream", "serve"):
        assert tr.events(tier=tier_name), \
            f"no spans from tier {tier_name!r}"
    # nesting: stream-tier spans (delta/refresh) are roots the driver
    # opens, so the nesting they prove is the engine/store work inside
    # them; engine, store, and serve spans must themselves be nested
    for tier_name in ("engine", "store", "serve"):
        assert any(e["args"]["depth"] > 0 for e in tr.events(tier=tier_name)), \
            f"no NESTED spans from tier {tier_name!r}"
    assert any(e["args"]["parent"] == "refresh"
               for e in tr.events(tier="store")), \
        "refresh repair did not nest store spans"
    assert any(e["args"]["parent"] == "serve.batch"
               for e in tr.events("cache", "serve"))
    assert any(e["args"]["parent"] == "extend"
               for e in tr.events("store.write", "store"))

    # --- export artifacts validate under the CI checker ----------------
    with tempfile.TemporaryDirectory() as d:
        m = obs.write_metrics(os.path.join(d, "metrics.json"))
        t = obs.write_trace(os.path.join(d, "trace.json"))
        check_metrics(m)
        check_trace(t, ["engine", "store", "stream", "serve"])

    print(json.dumps({
        "ok": True, "devices": n_dev, "mesh": args.mesh,
        "theta": int(r_on.theta),
        "spans": len(tr),
        "series": (len(snap["counters"]) + len(snap["gauges"])
                   + len(snap["histograms"])),
        "p50_ms": {n: snap["histograms"]
                   [f"serve.latency_ms{{tenant={n}}}"]["p50"]
                   for n in ("brand-a", "brand-b", "evolving")},
    }))


if __name__ == "__main__":
    sys.exit(main())
