"""ShardedStore (paper C1 end-to-end): sharded-vs-single-device
equivalence, per-shard growth invariants, true decremental sharded
selection, elastic snapshot/restore, the 2D (theta x vertex) layout
cells, and the forced multi-device subprocess cells.

These tests use meshes over however many devices the process has — 1 in
a plain run, 4 under scripts/ci.sh's
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` pass — and the
subprocess tests always exercise the real 4-shard (1D) and 8-device
2x4 (2D) layouts.
"""
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs.imm_snap import make_im_mesh, mesh_engine_kwargs
from repro.core.adaptive import l_pad_for
from repro.core.engine import InfluenceEngine, IMMConfig
from repro.core.selection import (
    select_dense, select_dense_sharded, select_sparse_sharded,
)
from repro.core.store import (
    BitmapStore, ShardedStore, make_store, store_from_state,
)
from repro.graphs import balanced_vertex_partition, rmat_graph

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def theta_mesh(shards: int = None):
    return jax.make_mesh((shards or jax.device_count(),), ("data",))


def im_mesh_2d():
    """A 2D theta x vertex mesh over the available devices: (D/2, 2) on
    even device counts (the CI forced-4-device pass -> 2x2), (1, 1) on a
    single device — the full 2D code path runs either way."""
    d = jax.device_count()
    return make_im_mesh((d // 2, 2) if d % 2 == 0 else (d, 1))


def mesh_kw(mesh):
    return mesh_engine_kwargs(mesh)


# ------------------------------------------------------------------ store ----

def test_sharded_store_matches_bitmap_counters_and_hits():
    """Same batches (incl. sizes not divisible by the shard count) into a
    BitmapStore and a ShardedStore: identical count, fused counter,
    coverage stats, and membership query answers."""
    rng = np.random.default_rng(0)
    n = 48
    bs, ss = BitmapStore(n), ShardedStore(n, mesh=theta_mesh())
    for B in (24, 10, 7, 64):
        batch = (rng.random((B, n)) < 0.2).astype(np.uint8)
        bs.add_batch(jnp.asarray(batch))
        ss.add_batch(jnp.asarray(batch))
    assert bs.count == ss.count == 105
    assert ss.count == int(ss.counts.sum())
    np.testing.assert_array_equal(np.asarray(bs.counter),
                                  np.asarray(ss.counter))
    assert bs.coverage_stats() == ss.coverage_stats()
    S = np.asarray([[0, 1, 2], [5, 5, 5], [7, 30, 12]], np.int32)
    np.testing.assert_allclose(np.asarray(bs.hits(S)), np.asarray(ss.hits(S)),
                               rtol=1e-6)


def test_sharded_store_per_shard_growth_and_layout():
    """cap_local is a power of two per shard; every device shard buffer
    is (cap_local, n) — the global arena never lives on one device."""
    n = 32
    ss = ShardedStore(n, mesh=theta_mesh())
    D = ss.D
    assert ss.capacity == D * ss.cap_local
    cap0 = ss.cap_local
    rng = np.random.default_rng(1)
    # force at least one per-shard doubling
    for _ in range(4):
        ss.add_batch(jnp.asarray(
            (rng.random((16 * D, n)) < 0.3).astype(np.uint8)))
    assert ss.cap_local > cap0 and ss.cap_local & (ss.cap_local - 1) == 0
    shards = ss.R.addressable_shards
    local_devices = len(jax.local_devices())
    assert len(shards) == local_devices
    assert all(s.data.shape == (ss.cap_local * D // local_devices, n)
               for s in shards)
    # valid mask counts exactly the stored rows, per shard
    assert int(np.asarray(ss.valid_mask()).sum()) == ss.count


def test_sharded_selection_matches_dense_both_methods():
    """Sharded rebuild AND true-decrement selection over the store's
    native shards == single-device dense selection (permutation-invariant
    exact integer reductions)."""
    rng = np.random.default_rng(2)
    n = 40
    mesh = theta_mesh()
    bs, ss = BitmapStore(n), ShardedStore(n, mesh=mesh)
    for B in (24, 9, 31):
        batch = (rng.random((B, n)) < 0.25).astype(np.uint8)
        bs.add_batch(jnp.asarray(batch))
        ss.add_batch(jnp.asarray(batch))
    vd, vs = bs.view(), ss.view()
    for method in ("rebuild", "decrement"):
        s1, f1, g1 = select_dense(vd.R, vd.valid, 6, method)
        s2, f2, g2 = select_dense_sharded(
            mesh, vs.R, vs.valid, 6, theta_axes=("data",), method=method)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        assert float(f1) == pytest.approx(float(f2))
    with pytest.raises(ValueError):
        select_dense_sharded(mesh, vs.R, vs.valid, 2, method="nope")


def test_sharded_store_state_roundtrips_across_layouts():
    rng = np.random.default_rng(3)
    n, mesh = 36, theta_mesh()
    ss = ShardedStore(n, mesh=mesh)
    ss.add_batch(jnp.asarray((rng.random((50, n)) < 0.3).astype(np.uint8)))
    st = ss.state()
    assert str(np.asarray(st["kind"])) == "sharded"
    assert st["R"].shape == (50, n)          # compact valid rows only
    # sharded -> sharded (same mesh)
    clone = store_from_state(st, mesh=mesh)
    assert isinstance(clone, ShardedStore) and clone.count == 50
    np.testing.assert_array_equal(np.asarray(clone.counter),
                                  np.asarray(ss.counter))
    # sharded -> single-device bitmap
    flat = store_from_state(st)
    assert isinstance(flat, BitmapStore) and flat.count == 50
    np.testing.assert_array_equal(np.asarray(flat.counter),
                                  np.asarray(ss.counter))
    # bitmap -> sharded
    resharded = store_from_state(flat.state(), mesh=mesh)
    assert isinstance(resharded, ShardedStore) and resharded.count == 50
    np.testing.assert_array_equal(np.asarray(resharded.counter),
                                  np.asarray(ss.counter))
    # index snapshots cannot land on a mesh
    idx = make_store("indices", n)
    idx.add_batch(jnp.asarray((rng.random((8, n)) < 0.1).astype(np.uint8)))
    with pytest.raises(ValueError):
        store_from_state(idx.state(), mesh=mesh)


def test_make_store_sharded_requires_mesh():
    assert isinstance(make_store("sharded", 16, mesh=theta_mesh()),
                      ShardedStore)
    with pytest.raises(TypeError):
        make_store("sharded", 16)
    with pytest.raises(ValueError):
        InfluenceEngine(rmat_graph(32, 64, seed=0),
                        IMMConfig(store="sharded"))


# ----------------------------------------------------------------- engine ----

def test_engine_sharded_run_seed_for_seed_equals_dense():
    """The headline C1 invariant through the whole engine: run() on a
    mesh == run() without one, bit for bit, for a fixed cfg.seed."""
    g = rmat_graph(128, 1024, seed=4)
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3)
    dense = InfluenceEngine(g, cfg)
    sharded = InfluenceEngine(g, cfg, mesh=theta_mesh())
    assert isinstance(sharded.store, ShardedStore)
    r1, r2 = dense.run(), sharded.run()
    np.testing.assert_array_equal(r1.seeds, r2.seeds)
    np.testing.assert_array_equal(r1.counter, r2.counter)
    assert r1.theta == r2.theta
    assert r1.covered_frac == pytest.approx(r2.covered_frac)
    np.testing.assert_allclose(
        dense.influences([r1.seeds[:2], r1.seeds]),
        sharded.influences([r1.seeds[:2], r1.seeds]), rtol=1e-6)


def test_engine_sharded_snapshot_restore_seed_for_seed():
    """Snapshot on a mesh, restore on a mesh / no mesh: selections and
    the continued sample stream stay identical to the dense engine."""
    g = rmat_graph(96, 768, seed=5)
    cfg = IMMConfig(k=4, batch=32, max_theta=128, seed=11)
    mesh = theta_mesh()
    dense = InfluenceEngine(g, cfg)
    sharded = InfluenceEngine(g, cfg, mesh=mesh)
    dense.extend(128)
    sharded.extend(128)
    want = dense.select(4)
    with tempfile.TemporaryDirectory() as d:
        sharded.snapshot(d)
        again = InfluenceEngine(g, cfg, mesh=mesh)
        assert again.restore(d)
        np.testing.assert_array_equal(again.select(4).seeds, want.seeds)
        flat = InfluenceEngine(g, cfg)
        assert flat.restore(d)
        assert isinstance(flat.store, BitmapStore)
        np.testing.assert_array_equal(flat.select(4).seeds, want.seeds)
        # the restored PRNG stream continues identically across layouts
        dense.extend(dense.theta + 64)
        again.extend(again.theta + 64)
        np.testing.assert_array_equal(
            np.asarray(dense.store.counter), np.asarray(again.store.counter))


def test_engine_prebuilt_sharded_store_implies_mesh():
    g = rmat_graph(64, 512, seed=6)
    store = ShardedStore(g.n, mesh=theta_mesh())
    engine = InfluenceEngine(g, IMMConfig(k=3, batch=32), store=store)
    assert engine.mesh is store.mesh
    engine.extend(64)
    sel = engine.select(3)
    assert len(set(sel.seeds.tolist())) == 3


# --------------------------------------------------- 2D (theta x vertex) ----

def test_2d_store_matches_bitmap_counters_and_hits():
    """Same batches into a BitmapStore and a 2D ShardedStore (vertex
    axis resident): identical count, fused counter, coverage stats, and
    membership answers — including an n not divisible by Dv (the padded
    columns must stay invisible)."""
    rng = np.random.default_rng(10)
    n = 49                      # odd over Dv=2 -> n_local 25, n_pad 50
    bs = BitmapStore(n)
    ss = make_store("sharded", n, mesh=im_mesh_2d(), vertex_axis="vertex")
    assert ss.n_pad == ss.Dv * ss.n_local >= n
    for B in (24, 10, 7, 64):
        batch = (rng.random((B, n)) < 0.2).astype(np.uint8)
        bs.add_batch(jnp.asarray(batch))
        ss.add_batch(jnp.asarray(batch))
    assert bs.count == ss.count == 105
    np.testing.assert_array_equal(np.asarray(bs.counter),
                                  np.asarray(ss.counter))
    assert bs.coverage_stats() == ss.coverage_stats()
    S = np.asarray([[0, 1, 2], [5, 5, 5], [7, 30, 12]], np.int32)
    np.testing.assert_allclose(np.asarray(bs.hits(S)), np.asarray(ss.hits(S)),
                               rtol=1e-6)


def test_2d_per_device_buffer_shapes():
    """The 2D acceptance invariant: every device buffer is
    (cap_local, n_local) — n/Dv vertex columns, never the full (theta, n)
    arena — for R, sizes, and the counter partials."""
    n = 64
    mesh = im_mesh_2d()
    ss = ShardedStore(n, mesh=mesh, vertex_axis="vertex")
    rng = np.random.default_rng(11)
    for _ in range(3):
        ss.add_batch(jnp.asarray(
            (rng.random((16 * ss.D, n)) < 0.3).astype(np.uint8)))
    n_devs = len(jax.local_devices())
    shards = ss.R.addressable_shards
    assert len(shards) == n_devs
    assert all(s.data.shape == (ss.cap_local, ss.n_local) for s in shards)
    assert all(s.data.shape == (1, ss.n_local)
               for s in ss._counter.addressable_shards)
    if ss.Dv > 1:
        assert ss.n_local < n          # columns genuinely split
    assert int(np.asarray(ss.valid_mask()).sum()) == ss.count


def test_2d_selection_matches_dense_dense_and_sparse():
    """2D sharded rebuild/decrement selection — dense bitmaps AND the
    sharded-sparse index-list strategy — equals single-device dense
    selection bit for bit."""
    rng = np.random.default_rng(12)
    n = 41
    mesh = im_mesh_2d()
    bs = BitmapStore(n)
    ss = ShardedStore(n, mesh=mesh, vertex_axis="vertex")
    for B in (24, 9, 31):
        batch = (rng.random((B, n)) < 0.25).astype(np.uint8)
        bs.add_batch(jnp.asarray(batch))
        ss.add_batch(jnp.asarray(batch))
    vd, vs = bs.view(), ss.view()
    iv = ss.index_view(l_pad_for(ss.max_local_size()))
    for method in ("rebuild", "decrement"):
        s1, f1, g1 = select_dense(vd.R, vd.valid, 6, method)
        s2, f2, g2 = select_dense_sharded(
            mesh, vs.R, vs.valid, 6, theta_axes=("data",),
            vertex_axis="vertex", method=method, n=n)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        assert float(f1) == pytest.approx(float(f2))
        s3, f3, g3 = select_sparse_sharded(
            mesh, iv.R, iv.valid, n, 6, theta_axes=("data",),
            vertex_axis="vertex", method=method)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s3))
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g3))


def test_2d_engine_run_seed_for_seed_equals_dense():
    """The headline 2D invariant through the whole engine: run() on a
    theta x vertex mesh == run() without one, bit for bit."""
    g = rmat_graph(128, 1024, seed=4)
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3)
    dense = InfluenceEngine(g, cfg)
    sharded = InfluenceEngine(g, cfg, **mesh_kw(im_mesh_2d()))
    assert isinstance(sharded.store, ShardedStore)
    assert sharded.vertex_axis == "vertex"
    r1, r2 = dense.run(), sharded.run()
    np.testing.assert_array_equal(r1.seeds, r2.seeds)
    np.testing.assert_array_equal(r1.counter, r2.counter)
    assert r1.theta == r2.theta
    np.testing.assert_allclose(
        dense.influences([r1.seeds[:2], r1.seeds]),
        sharded.influences([r1.seeds[:2], r1.seeds]), rtol=1e-6)


def test_2d_engine_adaptive_sharded_sparse_selection():
    """When C4 chooses indices on a mesh engine (low coverage, per-
    vertex-shard threshold), selection routes through the sharded-sparse
    strategy and still matches the single-device answer."""
    g = rmat_graph(256, 512, seed=8, weighted_ic="wc")   # tiny RRR sets
    # switch_ratio=2: indices wins once l_max * 2 < n_local, which holds
    # for this graph on every vertex-shard count the CI runs (1 and 2)
    cfg = IMMConfig(k=4, batch=64, max_theta=256, seed=9,
                    sparse_rep_min_n=1, backend="sparse", switch_ratio=2)
    dense = InfluenceEngine(g, cfg)
    sharded = InfluenceEngine(g, cfg, **mesh_kw(im_mesh_2d()))
    dense.extend(256)
    sharded.extend(256)
    a, b = dense.select(4), sharded.select(4)
    np.testing.assert_array_equal(a.seeds, b.seeds)
    assert b.representation == "indices"   # the C4 sparse path engaged


def test_cross_layout_snapshot_roundtrips_2d():
    """Snapshots are elastic across {none, 1D, 2D}: every pair restores
    with identical counters and selections, and the restored PRNG stream
    continues identically (the S3 acceptance cell)."""
    g = rmat_graph(96, 768, seed=5)
    cfg = IMMConfig(k=4, batch=32, max_theta=128, seed=11)
    mesh1, mesh2 = theta_mesh(), im_mesh_2d()
    engines = {
        "none": InfluenceEngine(g, cfg),
        "1d": InfluenceEngine(g, cfg, mesh=mesh1),
        "2d": InfluenceEngine(g, cfg, **mesh_kw(mesh2)),
    }
    for e in engines.values():
        e.extend(128)
    want = engines["none"].select(4)
    layouts = {
        "none": {}, "1d": {"mesh": mesh1}, "2d": mesh_kw(mesh2),
    }
    for src_name, src in engines.items():
        with tempfile.TemporaryDirectory() as d:
            src.snapshot(d)
            for dst_name, kw in layouts.items():
                dst = InfluenceEngine(g, cfg, **kw)
                assert dst.restore(d), (src_name, dst_name)
                np.testing.assert_array_equal(
                    dst.select(4).seeds, want.seeds)
                np.testing.assert_array_equal(
                    np.asarray(dst.store.counter),
                    np.asarray(src.store.counter))
                # the restored stream continues identically
                dst.extend(dst.theta + 32)
                ref = InfluenceEngine(g, cfg)
                ref.extend(128 + 32)
                np.testing.assert_array_equal(
                    np.asarray(dst.store.counter),
                    np.asarray(ref.store.counter))


# ------------------------------------------------ balanced vertex layout ----

def skewed_partition(n, dv, seed=13):
    """An edge-balanced partition from a genuinely skewed dst stream, so
    the block boundaries land away from the equal-block cuts."""
    rng = np.random.default_rng(seed)
    dst = np.minimum(rng.geometric(4.0 / n, size=8 * n), n - 1)
    return balanced_vertex_partition(n, dv, dst=dst)


def test_2d_balanced_store_matches_bitmap():
    """A balanced-layout ShardedStore answers every read — counter,
    coverage stats, membership hits, reverse touch — identically to a
    BitmapStore and to the equal-layout store, for an n whose balanced
    blocks are uneven and padded."""
    rng = np.random.default_rng(14)
    n, mesh = 49, im_mesh_2d()
    dv = mesh.shape["vertex"]
    part = skewed_partition(n, dv)
    bs = BitmapStore(n)
    eq = ShardedStore(n, mesh=mesh, vertex_axis="vertex")
    bal = ShardedStore(n, mesh=mesh, vertex_axis="vertex", partition=part)
    assert bal.partition is part
    assert bal.n_local == part.block and bal.n_pad == part.n_pad
    for B in (24, 10, 7, 64):
        batch = (rng.random((B, n)) < 0.2).astype(np.uint8)
        for s in (bs, eq, bal):
            s.add_batch(jnp.asarray(batch))
    assert bs.count == bal.count
    np.testing.assert_array_equal(np.asarray(bs.counter),
                                  np.asarray(bal.counter))
    assert bs.coverage_stats() == bal.coverage_stats()
    S = np.asarray([[0, 1, 2], [5, 5, 5], [7, 30, 12], [48, 48, 48]],
                   np.int32)
    np.testing.assert_allclose(np.asarray(bs.hits(S)),
                               np.asarray(bal.hits(S)), rtol=1e-6)
    # reverse touch: same row mask as the equal layout, vertex by vertex
    verts = jnp.asarray([0, 17, 48, 5], jnp.int32)
    vmask = jnp.asarray([True, True, True, False])
    np.testing.assert_array_equal(
        np.asarray(eq.rows_touching_cols(verts, vmask)),
        np.asarray(bal.rows_touching_cols(verts, vmask)))


def test_2d_balanced_selection_matches_dense():
    """Balanced-layout sharded selection — rebuild/decrement, dense
    bitmaps AND the C4 sharded-sparse index view — equals single-device
    dense selection bit for bit (the boundaries move, the argmax
    tie-break cannot)."""
    rng = np.random.default_rng(15)
    n, mesh = 41, im_mesh_2d()
    part = skewed_partition(n, mesh.shape["vertex"], seed=16)
    bs = BitmapStore(n)
    ss = ShardedStore(n, mesh=mesh, vertex_axis="vertex", partition=part)
    for B in (24, 9, 31):
        batch = (rng.random((B, n)) < 0.25).astype(np.uint8)
        bs.add_batch(jnp.asarray(batch))
        ss.add_batch(jnp.asarray(batch))
    vd, vs = bs.view(), ss.view()
    iv = ss.index_view(l_pad_for(ss.max_local_size()))
    for method in ("rebuild", "decrement"):
        s1, f1, g1 = select_dense(vd.R, vd.valid, 6, method)
        s2, f2, g2 = select_dense_sharded(
            mesh, vs.R, vs.valid, 6, theta_axes=("data",),
            vertex_axis="vertex", method=method, n=n, partition=part)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        assert float(f1) == pytest.approx(float(f2))
        s3, f3, g3 = select_sparse_sharded(
            mesh, iv.R, iv.valid, n, 6, theta_axes=("data",),
            vertex_axis="vertex", method=method, partition=part)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s3))
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g3))


def test_balanced_snapshot_roundtrips_elastically():
    """`state()` returns rows in global vertex order whatever the column
    layout, so snapshots restore across equal <-> balanced <-> bitmap
    with identical counters (the re-partitioning contract)."""
    rng = np.random.default_rng(17)
    n, mesh = 36, im_mesh_2d()
    part = skewed_partition(n, mesh.shape["vertex"], seed=18)
    bal = ShardedStore(n, mesh=mesh, vertex_axis="vertex", partition=part)
    bal.add_batch(jnp.asarray((rng.random((50, n)) < 0.3).astype(np.uint8)))
    st = bal.state()
    assert st["R"].shape == (50, n)          # global order, pads stripped
    want = np.asarray(bal.counter)
    # balanced -> single-device bitmap
    flat = store_from_state(st)
    assert isinstance(flat, BitmapStore)
    np.testing.assert_array_equal(np.asarray(flat.counter), want)
    # balanced -> equal-layout sharded
    eq = store_from_state(st, mesh=mesh, vertex_axis="vertex")
    assert eq.partition.is_equal
    np.testing.assert_array_equal(np.asarray(eq.counter), want)
    # equal -> balanced (fresh boundaries) and balanced -> balanced
    for src in (eq.state(), st):
        back = store_from_state(src, mesh=mesh, vertex_axis="vertex",
                                partition=part)
        assert back.partition is part
        np.testing.assert_array_equal(np.asarray(back.counter), want)


def test_2d_engine_adaptive_sparse_with_balanced_partition():
    """The C4 indices representation composes with the balanced layout:
    local index lists convert through the data-dependent block starts
    and still match the single-device answer."""
    g = rmat_graph(256, 512, seed=8, weighted_ic="wc")
    cfg = IMMConfig(k=4, batch=64, max_theta=256, seed=9,
                    sparse_rep_min_n=1, backend="sparse", switch_ratio=2,
                    partition="balanced")
    dense = InfluenceEngine(g, cfg)     # partition is inert off-mesh
    sharded = InfluenceEngine(g, cfg, **mesh_kw(im_mesh_2d()))
    assert not sharded.store.partition.is_equal
    dense.extend(256)
    sharded.extend(256)
    a, b = dense.select(4), sharded.select(4)
    np.testing.assert_array_equal(a.seeds, b.seeds)
    assert b.representation == "indices"   # the C4 sparse path engaged


def test_make_im_mesh_and_engine_kwargs():
    """--mesh spellings resolve as documented and clip gracefully."""
    assert make_im_mesh(None) is None and make_im_mesh(0) is None
    m1 = make_im_mesh(2)
    assert tuple(m1.axis_names) == ("data",)
    assert mesh_engine_kwargs(m1) == {"mesh": m1, "theta_axes": ("data",)}
    m2 = make_im_mesh("2x2")
    assert tuple(m2.axis_names) == ("data", "vertex")
    kw = mesh_engine_kwargs(m2)
    assert kw["theta_axes"] == ("data",) and kw["vertex_axis"] == "vertex"
    # pod-sized 2D flags clip to the local device count, vertex first:
    # theta sharding survives, the vertex axis shrinks into what's left
    d = jax.device_count()
    big = make_im_mesh(f"{d}x1024")
    assert big.shape["data"] == d and big.shape["vertex"] == 1
    big = make_im_mesh("1024x1024")
    assert int(np.prod([big.shape[a] for a in big.axis_names])) <= d
    assert big.shape["data"] == d      # theta won the clip
    # a Mesh passes through; tuples spell 2D too
    assert make_im_mesh(m2) is m2
    mt = make_im_mesh((1, 1))
    assert tuple(mt.axis_names) == ("data", "vertex")
    assert mesh_engine_kwargs(None) == {}
    with pytest.raises(ValueError):
        make_im_mesh("0x2")


# ---------------------------------------- forced multi-device subprocess ----

def _run_force_mesh(devices: int, mesh: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # drop any inherited device-count flag (the CI mesh pass exports =4;
    # XLA lets the later flag win, which would shrink our forced mesh)
    inherited = " ".join(
        f for f in env.get("XLA_FLAGS", "").split()
        if not f.startswith("--xla_force_host_platform_device_count"))
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={devices} "
        + inherited).strip()
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "force_mesh_check.py"),
         "--mesh", mesh],
        env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_sharded_store_forced_4dev_subprocess():
    """The 1D C1 acceptance cell: under a forced 4-device host platform
    the arena is physically split into 4 (cap_local, n) buffers and
    results stay seed-for-seed identical to BitmapStore + dense selection
    (see tests/force_mesh_check.py for the assertions)."""
    out = _run_force_mesh(4, "4")
    assert out["ok"] and out["devices"] == 4


def test_sharded_store_forced_8dev_2x4_subprocess():
    """The 2D acceptance cell: a forced-8-device 2x4 mesh splits the
    arena into 8 (cap_local, n/4) tiles — theta over 2 shards, vertices
    over 4 — and select(k)/influence(S) stay bitwise identical to the
    single-device engine (the full (theta, n) arena never exists on one
    device)."""
    out = _run_force_mesh(8, "2x4")
    assert out["ok"] and out["devices"] == 8
    assert out["n_local"] == 32        # ceil(128 / 4) vertex columns
