"""ShardedStore (paper C1 end-to-end): sharded-vs-single-device
equivalence, per-shard growth invariants, true decremental sharded
selection, elastic snapshot/restore, and the forced 4-device subprocess
cell.

These tests use meshes over however many devices the process has — 1 in
a plain run, 4 under scripts/ci.sh's
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` pass — and the
subprocess test always exercises the real 4-shard layout.
"""
import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.engine import InfluenceEngine, IMMConfig
from repro.core.selection import select_dense, select_dense_sharded
from repro.core.store import (
    BitmapStore, ShardedStore, make_store, store_from_state,
)
from repro.graphs import rmat_graph

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def theta_mesh(shards: int = None):
    return jax.make_mesh((shards or jax.device_count(),), ("data",))


# ------------------------------------------------------------------ store ----

def test_sharded_store_matches_bitmap_counters_and_hits():
    """Same batches (incl. sizes not divisible by the shard count) into a
    BitmapStore and a ShardedStore: identical count, fused counter,
    coverage stats, and membership query answers."""
    rng = np.random.default_rng(0)
    n = 48
    bs, ss = BitmapStore(n), ShardedStore(n, mesh=theta_mesh())
    for B in (24, 10, 7, 64):
        batch = (rng.random((B, n)) < 0.2).astype(np.uint8)
        bs.add_batch(jnp.asarray(batch))
        ss.add_batch(jnp.asarray(batch))
    assert bs.count == ss.count == 105
    assert ss.count == int(ss.counts.sum())
    np.testing.assert_array_equal(np.asarray(bs.counter),
                                  np.asarray(ss.counter))
    assert bs.coverage_stats() == ss.coverage_stats()
    S = np.asarray([[0, 1, 2], [5, 5, 5], [7, 30, 12]], np.int32)
    np.testing.assert_allclose(np.asarray(bs.hits(S)), np.asarray(ss.hits(S)),
                               rtol=1e-6)


def test_sharded_store_per_shard_growth_and_layout():
    """cap_local is a power of two per shard; every device shard buffer
    is (cap_local, n) — the global arena never lives on one device."""
    n = 32
    ss = ShardedStore(n, mesh=theta_mesh())
    D = ss.D
    assert ss.capacity == D * ss.cap_local
    cap0 = ss.cap_local
    rng = np.random.default_rng(1)
    # force at least one per-shard doubling
    for _ in range(4):
        ss.add_batch(jnp.asarray(
            (rng.random((16 * D, n)) < 0.3).astype(np.uint8)))
    assert ss.cap_local > cap0 and ss.cap_local & (ss.cap_local - 1) == 0
    shards = ss.R.addressable_shards
    local_devices = len(jax.local_devices())
    assert len(shards) == local_devices
    assert all(s.data.shape == (ss.cap_local * D // local_devices, n)
               for s in shards)
    # valid mask counts exactly the stored rows, per shard
    assert int(np.asarray(ss.valid_mask()).sum()) == ss.count


def test_sharded_selection_matches_dense_both_methods():
    """Sharded rebuild AND true-decrement selection over the store's
    native shards == single-device dense selection (permutation-invariant
    exact integer reductions)."""
    rng = np.random.default_rng(2)
    n = 40
    mesh = theta_mesh()
    bs, ss = BitmapStore(n), ShardedStore(n, mesh=mesh)
    for B in (24, 9, 31):
        batch = (rng.random((B, n)) < 0.25).astype(np.uint8)
        bs.add_batch(jnp.asarray(batch))
        ss.add_batch(jnp.asarray(batch))
    vd, vs = bs.view(), ss.view()
    for method in ("rebuild", "decrement"):
        s1, f1, g1 = select_dense(vd.R, vd.valid, 6, method)
        s2, f2, g2 = select_dense_sharded(
            mesh, vs.R, vs.valid, 6, theta_axes=("data",), method=method)
        np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        assert float(f1) == pytest.approx(float(f2))
    with pytest.raises(ValueError):
        select_dense_sharded(mesh, vs.R, vs.valid, 2, method="nope")


def test_sharded_store_state_roundtrips_across_layouts():
    rng = np.random.default_rng(3)
    n, mesh = 36, theta_mesh()
    ss = ShardedStore(n, mesh=mesh)
    ss.add_batch(jnp.asarray((rng.random((50, n)) < 0.3).astype(np.uint8)))
    st = ss.state()
    assert str(np.asarray(st["kind"])) == "sharded"
    assert st["R"].shape == (50, n)          # compact valid rows only
    # sharded -> sharded (same mesh)
    clone = store_from_state(st, mesh=mesh)
    assert isinstance(clone, ShardedStore) and clone.count == 50
    np.testing.assert_array_equal(np.asarray(clone.counter),
                                  np.asarray(ss.counter))
    # sharded -> single-device bitmap
    flat = store_from_state(st)
    assert isinstance(flat, BitmapStore) and flat.count == 50
    np.testing.assert_array_equal(np.asarray(flat.counter),
                                  np.asarray(ss.counter))
    # bitmap -> sharded
    resharded = store_from_state(flat.state(), mesh=mesh)
    assert isinstance(resharded, ShardedStore) and resharded.count == 50
    np.testing.assert_array_equal(np.asarray(resharded.counter),
                                  np.asarray(ss.counter))
    # index snapshots cannot land on a mesh
    idx = make_store("indices", n)
    idx.add_batch(jnp.asarray((rng.random((8, n)) < 0.1).astype(np.uint8)))
    with pytest.raises(ValueError):
        store_from_state(idx.state(), mesh=mesh)


def test_make_store_sharded_requires_mesh():
    assert isinstance(make_store("sharded", 16, mesh=theta_mesh()),
                      ShardedStore)
    with pytest.raises(TypeError):
        make_store("sharded", 16)
    with pytest.raises(ValueError):
        InfluenceEngine(rmat_graph(32, 64, seed=0),
                        IMMConfig(store="sharded"))


# ----------------------------------------------------------------- engine ----

def test_engine_sharded_run_seed_for_seed_equals_dense():
    """The headline C1 invariant through the whole engine: run() on a
    mesh == run() without one, bit for bit, for a fixed cfg.seed."""
    g = rmat_graph(128, 1024, seed=4)
    cfg = IMMConfig(k=5, batch=64, max_theta=256, seed=3)
    dense = InfluenceEngine(g, cfg)
    sharded = InfluenceEngine(g, cfg, mesh=theta_mesh())
    assert isinstance(sharded.store, ShardedStore)
    r1, r2 = dense.run(), sharded.run()
    np.testing.assert_array_equal(r1.seeds, r2.seeds)
    np.testing.assert_array_equal(r1.counter, r2.counter)
    assert r1.theta == r2.theta
    assert r1.covered_frac == pytest.approx(r2.covered_frac)
    np.testing.assert_allclose(
        dense.influences([r1.seeds[:2], r1.seeds]),
        sharded.influences([r1.seeds[:2], r1.seeds]), rtol=1e-6)


def test_engine_sharded_snapshot_restore_seed_for_seed():
    """Snapshot on a mesh, restore on a mesh / no mesh: selections and
    the continued sample stream stay identical to the dense engine."""
    g = rmat_graph(96, 768, seed=5)
    cfg = IMMConfig(k=4, batch=32, max_theta=128, seed=11)
    mesh = theta_mesh()
    dense = InfluenceEngine(g, cfg)
    sharded = InfluenceEngine(g, cfg, mesh=mesh)
    dense.extend(128)
    sharded.extend(128)
    want = dense.select(4)
    with tempfile.TemporaryDirectory() as d:
        sharded.snapshot(d)
        again = InfluenceEngine(g, cfg, mesh=mesh)
        assert again.restore(d)
        np.testing.assert_array_equal(again.select(4).seeds, want.seeds)
        flat = InfluenceEngine(g, cfg)
        assert flat.restore(d)
        assert isinstance(flat.store, BitmapStore)
        np.testing.assert_array_equal(flat.select(4).seeds, want.seeds)
        # the restored PRNG stream continues identically across layouts
        dense.extend(dense.theta + 64)
        again.extend(again.theta + 64)
        np.testing.assert_array_equal(
            np.asarray(dense.store.counter), np.asarray(again.store.counter))


def test_engine_prebuilt_sharded_store_implies_mesh():
    g = rmat_graph(64, 512, seed=6)
    store = ShardedStore(g.n, mesh=theta_mesh())
    engine = InfluenceEngine(g, IMMConfig(k=3, batch=32), store=store)
    assert engine.mesh is store.mesh
    engine.extend(64)
    sel = engine.select(3)
    assert len(set(sel.seeds.tolist())) == 3


# ------------------------------------------- forced 4-device subprocess ----

def test_sharded_store_forced_4dev_subprocess():
    """The C1 acceptance cell: under a forced 4-device host platform the
    arena is physically split into 4 (cap_local, n) buffers and results
    stay seed-for-seed identical to BitmapStore + dense selection (see
    tests/force_mesh_check.py for the assertions)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=4 "
                        + env.get("XLA_FLAGS", "")).strip()
    r = subprocess.run(
        [sys.executable, os.path.join(REPO, "tests", "force_mesh_check.py")],
        env=env, capture_output=True, text=True, timeout=540)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-2000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["ok"] and out["devices"] == 4
