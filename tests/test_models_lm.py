"""Transformer LM: forward/loss/prefill/decode consistency across paths."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.transformer import (
    LMConfig, init_lm, lm_forward, lm_loss, prefill, prefill_chunked,
    decode_step, init_kv_cache,
)
from repro.models.attention import blockwise_attention, apply_rope
from repro.kernels import ref as kref


CFG = LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
               vocab=128, remat=False)


def _toks(b=2, s=24, vocab=128, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (b, s), 0, vocab)


def test_chunked_ce_matches_full_logits():
    p = init_lm(jax.random.PRNGKey(0), CFG)
    toks = _toks()
    labels = jnp.concatenate(
        [toks[:, 1:], jnp.full((2, 1), -1, toks.dtype)], 1)
    logits, aux = lm_forward(p, CFG, toks)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
    nll = -jnp.take_along_axis(
        logp, jnp.maximum(labels, 0)[..., None], -1)[..., 0]
    mask = labels >= 0
    want = (nll * mask).sum() / mask.sum() + CFG.aux_loss_weight * aux
    got = lm_loss(p, CFG, toks, labels, ce_chunk=7)
    assert float(got) == pytest.approx(float(want), rel=1e-5)


def test_loss_grad_finite_all_variants():
    for cfg in [
        CFG,
        LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                 vocab=128, qkv_bias=True, remat=False),
        LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                 vocab=128, window=8, remat=False),
        LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                 vocab=128, n_experts=4, top_k=2, remat=False),
        LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                 vocab=128, emb_scale=12.0, residual_scale=0.3,
                 logit_scale=0.1, remat=False),
    ]:
        p = init_lm(jax.random.PRNGKey(0), cfg)
        toks = _toks(vocab=cfg.vocab)
        loss, grads = jax.value_and_grad(lm_loss)(p, cfg, toks, toks)
        assert np.isfinite(float(loss))
        assert all(bool(jnp.isfinite(g).all())
                   for g in jax.tree.leaves(grads))


def test_remat_equals_no_remat():
    cfg_r = LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2,
                     d_ff=64, vocab=128, remat=True)
    p = init_lm(jax.random.PRNGKey(0), CFG)
    toks = _toks()
    l1 = lm_loss(p, CFG, toks, toks)
    l2 = lm_loss(p, cfg_r, toks, toks)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)


def test_prefill_matches_forward_last_position():
    p = init_lm(jax.random.PRNGKey(0), CFG)
    toks = _toks()
    logits_full, _ = lm_forward(p, CFG, toks)
    logits_pre, cache = prefill(p, CFG, toks)
    np.testing.assert_allclose(np.asarray(logits_pre),
                               np.asarray(logits_full[:, -1]),
                               rtol=1e-4, atol=1e-5)
    assert cache["k"].shape == (2, 2, 2, 24, 8)   # (L, B, Hkv, S, hd)


@pytest.mark.parametrize("cfg,chunk", [
    (CFG, 8),
    (LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
              vocab=128, n_experts=4, top_k=2, capacity_factor=8.0,
              remat=False), 12),
    (LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
              vocab=128, window=8, remat=False), 8),
])
def test_chunked_prefill_matches_prefill(cfg, chunk):
    p = init_lm(jax.random.PRNGKey(0), cfg)
    toks = _toks(s=24, vocab=cfg.vocab)
    l1, _ = prefill(p, cfg, toks)
    l2, _ = prefill_chunked(p, cfg, toks, chunk=chunk)
    # bf16 KV-cache rounding bounds the divergence
    assert np.max(np.abs(np.asarray(l1) - np.asarray(l2))) < 0.06


def test_decode_matches_teacher_forcing():
    """Greedy decode logits equal full-forward logits position by position."""
    p = init_lm(jax.random.PRNGKey(0), CFG)
    toks = _toks(b=1, s=10)
    logits_full, _ = lm_forward(p, CFG, toks)
    cache = init_kv_cache(CFG, 1, 16, dtype=jnp.float32)
    preds = []
    for i in range(10):
        nxt, cache = decode_step(p, CFG, cache, toks[:, i:i + 1])
        preds.append(int(nxt[0, 0]))
    want = np.asarray(jnp.argmax(logits_full, -1))[0]
    np.testing.assert_array_equal(np.array(preds), want)


def test_swa_ring_buffer_decode():
    """With window=W, decoding past W positions matches a fresh prefill of
    the last W tokens (ring buffer correctness)."""
    cfg = LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                   vocab=128, window=8, remat=False)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    toks = _toks(b=1, s=20, vocab=128)
    cache = init_kv_cache(cfg, 1, cfg.window, dtype=jnp.float32)
    for i in range(20):
        nxt, cache = decode_step(p, cfg, cache, toks[:, i:i + 1])
    # reference: full forward with SWA, last position
    logits_full, _ = lm_forward(p, cfg, toks)
    want = int(jnp.argmax(logits_full[0, -1]))
    assert int(nxt[0, 0]) == want


def test_blockwise_attention_q_offset():
    """Chunk-level causality: q_offset positions the queries absolutely."""
    keys = jax.random.split(jax.random.PRNGKey(0), 3)
    k = jax.random.normal(keys[1], (1, 2, 32, 8))
    v = jax.random.normal(keys[2], (1, 2, 32, 8))
    q_all = jax.random.normal(keys[0], (1, 2, 32, 8))
    full = kref.attention_ref(q_all, k, v, causal=True)
    # second 16-query chunk with offset 16 must equal rows 16: of the full
    got = blockwise_attention(q_all[:, :, 16:], k, v, causal=True,
                              chunk=8, q_offset=16)
    np.testing.assert_allclose(np.asarray(got), np.asarray(full[:, :, 16:]),
                               rtol=2e-3, atol=2e-3)


def test_rope_orthogonality():
    """RoPE preserves norms and relative-position inner products."""
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 1, 6, 16))
    pos = jnp.arange(6)
    y = apply_rope(x, pos[None, None, :])
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-5)
    # <rope(a,i), rope(b,j)> depends only on (i - j)
    a = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    b = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    def ip(i, j):
        ra = apply_rope(a, jnp.array([[[i]]]))
        rb = apply_rope(b, jnp.array([[[j]]]))
        return float(jnp.sum(ra * rb))
    assert ip(3, 1) == pytest.approx(ip(7, 5), rel=1e-4)


def test_param_count_formula_matches_init():
    from repro.models.common import count_params
    for cfg in (CFG,
                LMConfig(n_layers=3, d_model=48, n_heads=6, n_kv_heads=2,
                         d_ff=96, vocab=300, n_experts=4, top_k=2)):
        p = init_lm(jax.random.PRNGKey(0), cfg)
        # formula excludes qkv biases (zero-init) and router (counted)
        got = count_params(p)
        want = cfg.param_count()
        assert abs(got - want) / want < 0.02, (got, want)


def test_moe_shard_map_matches_dense_path():
    """shard_map MoE ('ep' and the token-regathering 'tpe') == the GSPMD
    dense dispatch on a 1-device mesh."""
    import dataclasses
    from repro.models import moe_sharded
    cfg = LMConfig(n_layers=2, d_model=32, n_heads=4, n_kv_heads=4,
                   d_ff=64, vocab=128, n_experts=4, top_k=2, remat=False)
    p = init_lm(jax.random.PRNGKey(0), cfg)
    toks = _toks(vocab=128)
    l_ref = float(lm_loss(p, cfg, toks, toks))
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    moe_sharded.MESH = mesh
    for part in ("tpe", "ep"):
        cfg2 = dataclasses.replace(cfg, moe_impl="shard_map",
                                   moe_shard_axes=("data",),
                                   moe_partition=part)
        with mesh:
            l = float(lm_loss(p, cfg2, toks, toks))
            grads = jax.grad(lm_loss)(p, cfg2, toks, toks)
        assert abs(l - l_ref) < 1e-4, (part, l, l_ref)
        assert all(bool(jnp.isfinite(g).all())
                   for g in jax.tree.leaves(grads))


def test_sort_based_routing_matches_onehot_reference():
    """Sort-based slot assignment == the dense one-hot cumsum reference."""
    T, k, E, C = 64, 2, 8, 12
    key = jax.random.PRNGKey(3)
    gate_idx = jax.random.randint(key, (T, k), 0, E)
    # reference: one-hot cumsum positions
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)
    flat_oh = onehot.reshape(T * k, E)
    pos_ref = ((jnp.cumsum(flat_oh, axis=0) - flat_oh)
               .reshape(T, k, E) * onehot).sum(-1).astype(jnp.int32)
    # sort-based (transformer._moe_ffn internals)
    flat_eid = gate_idx.reshape(-1)
    order = jnp.argsort(flat_eid, stable=True)
    sorted_eid = flat_eid[order]
    seg_start = jnp.searchsorted(sorted_eid,
                                 jnp.arange(E, dtype=sorted_eid.dtype))
    pos_sorted = (jnp.arange(T * k, dtype=jnp.int32)
                  - seg_start[sorted_eid].astype(jnp.int32))
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted)
    np.testing.assert_array_equal(np.asarray(pos.reshape(T, k)),
                                  np.asarray(pos_ref))
