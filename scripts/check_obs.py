#!/usr/bin/env python
"""Validate IMTrace export artifacts (docs/observability.md).

Checks a metrics-registry JSON snapshot (``--metrics-out``) against the
schema `repro.obs.MetricsRegistry.snapshot` promises — ``counters`` /
``gauges`` / ``histograms`` maps with the right per-series shapes, exact
cumulative bucket counts — and a ``--trace-out`` file for being valid
Chrome trace-event JSON (the format Perfetto / chrome://tracing load):
a ``traceEvents`` list of ``ph: "M"`` metadata and ``ph: "X"`` complete
events with microsecond ``ts``/``dur``, plus at least one span from
every tier named in ``--tiers``.

    python scripts/check_obs.py --metrics M.json --trace T.json \
        --tiers engine,store,serve --require-counter kernels.dispatch

Either artifact may be omitted; exits non-zero with a pointed message on
the first violation.  ``--require-counter`` (repeatable) additionally
asserts a named counter series is present in the metrics snapshot — CI
uses it to prove the ``kernels.dispatch`` impl accounting survives all
the way into exported artifacts.  CI runs this against the artifacts a tiny launch
campaign exports (scripts/ci.sh).
"""
from __future__ import annotations

import argparse
import json
import sys

NUM = (int, float)


def fail(msg: str):
    sys.exit(f"check_obs: {msg}")


def check_metrics(path: str, require_counters: list[str] = ()) -> str:
    with open(path) as f:
        snap = json.load(f)
    if not isinstance(snap, dict):
        fail(f"{path}: snapshot must be a JSON object, got {type(snap)}")
    for section in ("counters", "gauges", "histograms"):
        if not isinstance(snap.get(section), dict):
            fail(f"{path}: missing/invalid {section!r} map")
    for key, v in snap["counters"].items():
        if not isinstance(v, int) or v < 0:
            fail(f"{path}: counter {key!r} must be a non-negative int: {v}")
    for key, v in snap["gauges"].items():
        if not isinstance(v, dict) or not all(
                isinstance(v.get(f), NUM) for f in ("value", "max")):
            fail(f"{path}: gauge {key!r} must carry numeric value/max: {v}")
    for key, h in snap["histograms"].items():
        for f in ("count", "sum", "min", "max", "p50", "p99"):
            if not isinstance(h.get(f), NUM):
                fail(f"{path}: histogram {key!r} missing numeric {f!r}")
        buckets = h.get("buckets")
        if not isinstance(buckets, list) or not buckets:
            fail(f"{path}: histogram {key!r} has no buckets")
        if buckets[-1][0] != "+Inf":
            fail(f"{path}: histogram {key!r} must end in a +Inf bucket")
        if sum(c for _, c in buckets) != h["count"]:
            fail(f"{path}: histogram {key!r} bucket counts do not sum "
                 f"to count={h['count']}")
    for name in require_counters:
        # a bare name matches itself or any labeled series of that name
        # (series keys render labels as "name{k=v,...}")
        if not any(key == name or key.startswith(name + "{")
                   for key in snap["counters"]):
            fail(f"{path}: required counter {name!r} absent "
                 f"(saw {sorted(snap['counters'])})")
    n = (len(snap["counters"]) + len(snap["gauges"])
         + len(snap["histograms"]))
    return (f"metrics OK: {len(snap['counters'])} counters, "
            f"{len(snap['gauges'])} gauges, "
            f"{len(snap['histograms'])} histograms ({n} series)")


def check_trace(path: str, tiers: list[str]) -> str:
    with open(path) as f:
        trace = json.load(f)
    if not isinstance(trace, dict) or not isinstance(
            trace.get("traceEvents"), list):
        fail(f"{path}: not Chrome trace-event JSON "
             f"(object with a traceEvents list)")
    spans = 0
    seen_tiers = set()
    for i, ev in enumerate(trace["traceEvents"]):
        ph = ev.get("ph")
        if ph == "M":
            continue
        if ph != "X":
            fail(f"{path}: event {i} has ph={ph!r}, expected 'M' or 'X'")
        for f in ("name", "cat", "ts", "dur", "pid", "tid"):
            if f not in ev:
                fail(f"{path}: span event {i} ({ev.get('name')!r}) "
                     f"missing {f!r}")
        if not isinstance(ev["ts"], NUM) or not isinstance(ev["dur"], NUM):
            fail(f"{path}: span event {i} has non-numeric ts/dur")
        spans += 1
        seen_tiers.add(ev["cat"])
    if spans == 0:
        fail(f"{path}: trace has no spans")
    missing = [t for t in tiers if t not in seen_tiers]
    if missing:
        fail(f"{path}: no spans from tier(s) {missing} "
             f"(saw {sorted(seen_tiers)})")
    return (f"trace OK: {spans} spans across tiers {sorted(seen_tiers)}")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--metrics", default=None,
                    help="metrics-registry JSON snapshot to validate")
    ap.add_argument("--trace", default=None,
                    help="Chrome trace-event JSON to validate")
    ap.add_argument("--tiers", default="engine,store,serve",
                    help="comma-separated tiers the trace must contain "
                         "at least one span from")
    ap.add_argument("--require-counter", action="append", default=[],
                    metavar="NAME",
                    help="fail unless the metrics snapshot contains this "
                         "counter (exact series key, or a bare name that "
                         "matches any 'NAME{...}' labeled series); "
                         "repeatable")
    args = ap.parse_args(argv)
    if not args.metrics and not args.trace:
        fail("nothing to check: pass --metrics and/or --trace")
    if args.require_counter and not args.metrics:
        fail("--require-counter needs --metrics")
    tiers = [t for t in args.tiers.split(",") if t]
    if args.metrics:
        print(check_metrics(args.metrics, args.require_counter))
    if args.trace:
        print(check_trace(args.trace, tiers))


if __name__ == "__main__":
    main()
