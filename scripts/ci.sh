#!/usr/bin/env bash
# Tier-1 CI gate: bytecode-compile everything under src, then run the fast
# test suite (slow production cells are deselected; run them explicitly
# with `pytest -m slow`).  Extra args pass through to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" "$@"
