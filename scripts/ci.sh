#!/usr/bin/env bash
# Tier-1 CI gate: bytecode-compile everything under src, run the fast test
# suite (slow production cells are deselected; run them explicitly with
# `pytest -m slow`), re-run the mesh-touching tests on a forced 4-device
# host platform so the sharded code paths execute with real multi-device
# buffers on CPU-only runners, and check that docs references resolve.
# Extra args pass through to the main pytest invocation.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" "$@"

# mesh code paths under a forced 4-device host mesh (paper C1 layouts):
# ShardedStore, sharded selection, the engine equivalence tests, and the
# streaming subsystem (per-shard invalidation/eviction/compaction and the
# refresh-equivalence cells) all run with the theta axis physically split
# 4 ways
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -q -m "not slow" \
        tests/test_sharded_store.py \
        tests/test_stream.py \
        "tests/test_engine_store.py::test_sharded_strategy_through_engine_matches_local" \
        "tests/test_sharded_and_integration.py::test_select_dense_sharded_equals_local"

# streaming benchmark smoke (tiny evolving graph; the non-slow analogue of
# the full benchmarks/stream_runtime.py run) — exercises delta apply,
# row-granular refresh, and the bounded-memory mode end-to-end
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.stream_runtime --tiny \
        --out "${TMPDIR:-/tmp}/BENCH_3.json"

# docs health: files referenced from README/docs must exist
python scripts/check_docs.py
