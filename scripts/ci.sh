#!/usr/bin/env bash
# Tier-1 CI gate: bytecode-compile everything under src, run the fast test
# suite (slow production cells are deselected; run them explicitly with
# `pytest -m slow`), re-run the mesh-touching tests on a forced 4-device
# host platform so the sharded code paths execute with real multi-device
# buffers on CPU-only runners, and check that docs references resolve.
# Extra args pass through to the main pytest invocation.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" "$@"

# mesh code paths under a forced 4-device host mesh (paper C1 layouts):
# ShardedStore, sharded selection, and the engine equivalence tests all
# run with the theta axis physically split 4 ways
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -q -m "not slow" \
        tests/test_sharded_store.py \
        "tests/test_engine_store.py::test_sharded_strategy_through_engine_matches_local" \
        "tests/test_sharded_and_integration.py::test_select_dense_sharded_equals_local"

# docs health: files referenced from README/docs must exist
python scripts/check_docs.py
