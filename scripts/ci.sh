#!/usr/bin/env bash
# Tier-1 CI gate: bytecode-compile everything under src, run the fast test
# suite (slow production cells are deselected; run them explicitly with
# `pytest -m slow`), re-run the mesh-touching tests on a forced 4-device
# host platform so the sharded code paths execute with real multi-device
# buffers on CPU-only runners, and check that docs references resolve.
# Extra args pass through to the main pytest invocation.
set -euo pipefail
cd "$(dirname "$0")/.."

python -m compileall -q src

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" "$@"

# mesh code paths under a forced 4-device host mesh (paper C1 layouts):
# ShardedStore (1D and 2x2 theta x vertex), sharded selection (dense and
# sharded-sparse), the engine equivalence tests, the streaming subsystem
# (per-shard invalidation/eviction/compaction, refresh-equivalence and
# cross-layout snapshot-provenance cells incl. 2D), the sampler
# model x backend x stable matrix (legacy goldens + per-cell mesh
# equivalence), and the IMPack suite (codec round-trips, encoded mesh
# tiles, the compress-before-evict ladder, snapshot elasticity) all run
# with the theta axis physically split 4 ways
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m pytest -q -m "not slow" \
        tests/test_sharded_store.py \
        tests/test_stream.py \
        tests/test_sampler_matrix.py \
        tests/test_pack.py \
        tests/test_fused_pipeline.py \
        "tests/test_engine_store.py::test_sharded_strategy_through_engine_matches_local" \
        "tests/test_sharded_and_integration.py::test_select_dense_sharded_equals_local"

# the 2D acceptance cell on a forced-8-device 2x4 mesh: theta over 2
# shards x vertices over 4 — per-device arena buffers are (cap_local,
# n/4), the full (theta, n) arena never exists on one device, and
# select/influence answers are bitwise identical to the single-device
# engine (tests/force_mesh_check.py asserts all of it); the packed and
# compressed cells re-prove it with IMPack-encoded tiles, whose
# per-device buffers are (cap_local, w_local) at the codec width
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
    python tests/force_mesh_check.py --mesh 2x4
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
    python tests/force_mesh_check.py --mesh 2x4 --store packed
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
    python tests/force_mesh_check.py --mesh 2x4 --store compressed

# sharding-scaling benchmark smoke (BENCH_5): every mesh factorization of
# 8 forced devices (1, 8, 8x1, 4x2, 2x4, 1x8) runs the same workload —
# vertex-sharded layouts in both equal and edge-balanced (+bal) column
# layouts — with identical seeds asserted, reporting wall time, arena
# bytes per device, per-tile edge imbalance, and the per-step
# collective/compute breakdown; the run itself asserts balanced <= equal
# imbalance on the rmat graph
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
    python -m benchmarks.sharding_scaling --tiny \
        --out "${TMPDIR:-/tmp}/BENCH_5.json"

# step-time-breakdown schema gate: every BENCH_5 row must carry the
# imbalance + collective_s/compute_s fields the overlap work reports
python - "${TMPDIR:-/tmp}/BENCH_5.json" <<'PY'
import json, sys
rows = json.load(open(sys.argv[1]))
assert rows, "BENCH_5.json has no rows"
for row in rows:
    missing = [k for k in ("imbalance", "collective_s", "compute_s")
               if k not in row]
    assert not missing, f"row {row.get('mesh')} missing {missing}"
print(f"BENCH_5 schema OK: {len(rows)} rows carry "
      f"imbalance/collective_s/compute_s")
PY

# IMPack memory benchmark smoke (BENCH_9): bitmap vs packed vs
# compressed arenas on every layout of the 8 forced devices (1, 1D 8,
# 2D 2x4) — identical seeds asserted per cell, packed >= 4x fewer
# bytes_per_device than bitmap asserted per layout, plus the
# quality-per-byte curve rows
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
    python -m benchmarks.pack_memory --tiny \
        --out "${TMPDIR:-/tmp}/BENCH_9.json"

# fused RRR pipeline smoke (BENCH_10): the one-chain sample->write->count
# path vs the legacy two-call path at identical seeds — the emitter itself
# asserts bitwise-equal counters, seed sets, covered_frac and influence
# before writing a row — first single-device, then on a forced 4-device
# 2x2 theta x vertex mesh
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.kernel_pipeline --tiny \
        --out "${TMPDIR:-/tmp}/BENCH_10.json"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m benchmarks.kernel_pipeline --tiny --mesh 2x2 \
        --out "${TMPDIR:-/tmp}/BENCH_10.json"

# fused-pipeline schema gate: every BENCH_10 row must carry the kernel /
# fused / impl / achieved_frac fields the roofline layer reports, and the
# optional-key validation in benchmarks/_emit.py must have let them pass
python - "${TMPDIR:-/tmp}/BENCH_10.json" <<'PY'
import json, sys
rows = json.load(open(sys.argv[1]))
assert rows, "BENCH_10.json has no rows"
for row in rows:
    missing = [k for k in ("kernel", "fused", "impl", "achieved_frac")
               if k not in row]
    assert not missing, f"row {row.get('name')} missing {missing}"
    assert row["impl"] in ("pallas", "interpret", "oracle"), row
    assert 0.0 <= row["achieved_frac"] <= 1.0, row
fused = [r for r in rows if r.get("fused")]
assert fused and all("speedup" in r for r in fused), \
    "fused rows must report speedup vs the unfused twin"
print(f"BENCH_10 schema OK: {len(rows)} rows carry "
      f"kernel/fused/impl/achieved_frac")
PY

# streaming benchmark smoke (tiny evolving graph; the non-slow analogue of
# the full benchmarks/stream_runtime.py run) — exercises delta apply,
# row-granular refresh, and the bounded-memory mode end-to-end
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.stream_runtime --tiny \
        --out "${TMPDIR:-/tmp}/BENCH_3.json"

# sampler-matrix benchmark smoke: every coin model across the dense /
# sparse / pallas backends (plus the LT walk) through the engine —
# exercises the Pallas ic_frontier dispatch end-to-end off-TPU
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.sampler_matrix --tiny \
        --out "${TMPDIR:-/tmp}/BENCH_4.json"

# serve-tier smoke (IMServe): a tiny multi-tenant trace — static +
# streaming tenants, interleaved deltas, a relaxed-SLO replica tenant,
# background SLO-aware refresh — through the launch CLI and the BENCH_6
# emitter, first on the default single-device engines...
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --workload tier \
        --tenants 3 --tier-n 128 --max-theta 256 --duration 0.25 \
        --qps 64 --refresh-budget 128 --replicas 1
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.serve_tier --tiny \
        --out "${TMPDIR:-/tmp}/BENCH_6.json"

# ...then with every tenant engine (and its replica fan-out) on a forced
# 4-device 2x2 theta x vertex mesh — the serving tier is layout-agnostic
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m repro.launch.serve --workload tier \
        --tenants 3 --tier-n 128 --max-theta 256 --duration 0.25 \
        --qps 64 --refresh-budget 128 --replicas 1 --mesh 2x2
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="--xla_force_host_platform_device_count=4${XLA_FLAGS:+ $XLA_FLAGS}" \
    python -m benchmarks.serve_tier --tiny --mesh 2x2 \
        --out "${TMPDIR:-/tmp}/BENCH_6.json"

# IMTrace (repro.obs) export path: a small IMM campaign with
# --metrics-out/--trace-out, then the artifact gate — the metrics
# snapshot must match the registry schema and the trace must parse as
# Chrome trace-event JSON with spans from the engine and store tiers
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.im_run --graph com-Amazon --scale 0.002 \
        --k 4 --max-theta 256 \
        --metrics-out "${TMPDIR:-/tmp}/obs_metrics.json" \
        --trace-out "${TMPDIR:-/tmp}/obs_trace.json"
python scripts/check_obs.py \
    --metrics "${TMPDIR:-/tmp}/obs_metrics.json" \
    --trace "${TMPDIR:-/tmp}/obs_trace.json" --tiers engine,store \
    --require-counter kernels.dispatch

# ...and the serving tier under the same flags: the trace must now also
# carry stream (deltas + refresh) and serve (admission/cache/batch) spans
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m repro.launch.serve --workload tier \
        --tenants 3 --tier-n 128 --max-theta 256 --duration 0.25 \
        --qps 64 --refresh-budget 128 --replicas 1 \
        --metrics-out "${TMPDIR:-/tmp}/obs_metrics.json" \
        --trace-out "${TMPDIR:-/tmp}/obs_trace.json"
python scripts/check_obs.py \
    --metrics "${TMPDIR:-/tmp}/obs_metrics.json" \
    --trace "${TMPDIR:-/tmp}/obs_trace.json" \
    --tiers engine,store,stream,serve

# the observability acceptance cell on the forced-8-device 2x4 mesh:
# obs fully enabled is seed-for-seed bitwise identical to obs disabled,
# nested spans land from every tier, and a meshed IMServe campaign
# reports per-tenant latency quantiles, cache hit/miss, queue depth,
# and SLO violations (tests/force_obs_check.py asserts all of it)
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=8" \
    python tests/force_obs_check.py --mesh 2x4

# docs health: files referenced from README/docs must exist
python scripts/check_docs.py
