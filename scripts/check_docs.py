#!/usr/bin/env python
"""Docs link checker (CI): files referenced from README/docs must exist.

Scans README.md and docs/**/*.md for
  * relative markdown links ``[text](path)`` (external URLs and #anchors
    are skipped), resolved against the referencing file;
  * backticked repo paths like ``src/repro/core/store.py`` — the code
    references the docs make must resolve against the repo root.

Exits non-zero listing every dangling reference.
"""
from __future__ import annotations

import pathlib
import re
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_PATH = re.compile(
    r"`((?:src|docs|scripts|tests|examples|benchmarks|experiments)"
    r"/[\w./-]+\.(?:py|md|sh|txt|json))`")


def doc_files():
    files = [REPO / "README.md"]
    files += sorted((REPO / "docs").rglob("*.md"))
    return [f for f in files if f.exists()]


def check(path: pathlib.Path) -> list[str]:
    errors = []
    text = path.read_text()
    for target in MD_LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        target = target.split("#", 1)[0]
        if not target:
            continue
        resolved = (path.parent / target).resolve()
        if not resolved.exists():
            errors.append(f"{path.relative_to(REPO)}: broken link -> {target}")
    for target in CODE_PATH.findall(text):
        if not (REPO / target).exists():
            errors.append(
                f"{path.relative_to(REPO)}: missing code ref -> {target}")
    return errors


def main() -> int:
    files = doc_files()
    if not any(f.parent.name == "docs" for f in files):
        print("check_docs: no docs/*.md found", file=sys.stderr)
        return 1
    errors = [e for f in files for e in check(f)]
    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    print(f"check_docs: {len(files)} files, "
          f"{'FAIL' if errors else 'OK'} ({len(errors)} dangling refs)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
