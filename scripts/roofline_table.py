"""Build the EXPERIMENTS.md §Roofline table from experiments/cells/*.json.

    PYTHONPATH=src python scripts/roofline_table.py [--md]
    PYTHONPATH=src python scripts/roofline_table.py --peaks [--md]

``--peaks`` prints the ``device_kind``-keyed hardware peak table
(``repro.launch.roofline.HW_PEAKS``) that ``achieved_frac`` and the
BENCH_10 kernel-pipeline rows are normalized against, instead of the
dry-run cell table.
"""
import argparse
import glob
import json


def load_cells(pattern="experiments/cells/*.json"):
    rows = []
    for f in sorted(glob.glob(pattern)):
        for r in json.load(open(f)):
            rows.append(r)
    return rows


def fmt(rows, md=False):
    hdr = ["arch", "shape", "mesh", "fits", "GB/dev",
           "compute_s", "memory_s(adj)", "collective_s", "dominant",
           "useful", "frac"]
    out = []
    for r in rows:
        if not r.get("ok"):
            out.append([r["arch"], r["shape"], r["mesh"], "FAIL",
                        "-", "-", "-", "-",
                        r.get("error", "")[:40], "-", "-"])
            continue
        rf = r["roofline"]
        out.append([
            r["arch"], r["shape"], r["mesh"],
            "yes" if r["fits_hbm"] else "NO",
            f"{r['bytes_per_device']/2**30:.2f}",
            f"{rf['compute_s']:.4f}",
            f"{rf['memory_s']:.3f} ({rf['memory_adjusted_s']:.3f})",
            f"{rf['collective_s']:.3f}",
            rf["dominant_adjusted"].replace("_s", ""),
            f"{rf['useful_flops_ratio']:.2f}",
            f"{rf['roofline_fraction_adjusted']:.3f}",
        ])
    if md:
        lines = ["| " + " | ".join(hdr) + " |",
                 "|" + "|".join(["---"] * len(hdr)) + "|"]
        for r in out:
            lines.append("| " + " | ".join(str(c) for c in r) + " |")
        return "\n".join(lines)
    widths = [max(len(str(h)), *(len(str(r[i])) for r in out))
              for i, h in enumerate(hdr)]
    lines = ["  ".join(str(h).ljust(w) for h, w in zip(hdr, widths))]
    for r in out:
        lines.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(lines)


def fmt_peaks(md=False):
    from repro.launch.roofline import HW_PEAKS
    hdr = ["device_kind", "name", "peak_bf16_TFLOP/s", "HBM_GB/s",
           "ICI_GB/s", "HBM_GiB"]
    out = [[k, hw["name"],
            f"{hw['peak_flops_bf16']/1e12:.1f}",
            f"{hw['hbm_bytes_per_s']/1e9:.0f}",
            f"{hw['ici_bytes_per_s']/1e9:.0f}",
            f"{hw['hbm_bytes']/2**30:.0f}"]
           for k, hw in HW_PEAKS.items()]
    if md:
        lines = ["| " + " | ".join(hdr) + " |",
                 "|" + "|".join(["---"] * len(hdr)) + "|"]
        for r in out:
            lines.append("| " + " | ".join(r) + " |")
        return "\n".join(lines)
    widths = [max(len(str(h)), *(len(str(r[i])) for r in out))
              for i, h in enumerate(hdr)]
    lines = ["  ".join(str(h).ljust(w) for h, w in zip(hdr, widths))]
    for r in out:
        lines.append("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
    return "\n".join(lines)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--md", action="store_true")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--peaks", action="store_true",
                    help="print the device_kind-keyed hardware peak table")
    args = ap.parse_args()
    if args.peaks:
        print(fmt_peaks(md=args.md))
    else:
        rows = load_cells()
        if args.mesh:
            rows = [r for r in rows if r.get("mesh") == args.mesh]
        print(fmt(rows, md=args.md))
